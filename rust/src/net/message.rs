//! Wire protocol: length-prefixed binary frames, hand-rolled codec (no
//! serde offline). All multi-byte integers are little-endian.
//!
//! # Protocol versions
//!
//! **v1** (the original protocol) is leader-speaks-first: the worker
//! connects silently, the leader sends [`Message::Join`], and every
//! upload is an untagged [`Message::Update`] decoded with the single
//! connection-wide client codec.
//!
//! **v2** adds per-worker codec negotiation: the worker speaks first
//! with [`Message::Hello`] (its protocol version plus an optional
//! device-tier name and/or an explicit `quant_client` spec), the leader
//! answers with [`Message::JoinV2`] carrying the resolved per-worker
//! codec spec *and* its registry id, and every upload is a
//! [`Message::UpdateV2`] tagged with that `codec_id` so the leader
//! routes it through the server's codec registry
//! ([`crate::coordinator::Server::ingest_from`]) instead of guessing a
//! wire format from the payload size.
//!
//! A v1 worker never sends `Hello` (the tag does not exist in v1), so
//! the leader detects v1 peers by their initial silence and serves them
//! the v1 frames bit-identically. Conversely a `Hello` or `JoinV2`
//! frame claiming a version below 2 is malformed by construction and is
//! rejected at decode time.

use crate::quant::QuantizedMsg;
use anyhow::{anyhow, bail, Result};

/// The highest protocol version this build speaks. Both ends advertise
/// their version and the connection runs at the minimum of the two.
pub const PROTOCOL_VERSION: u8 = 2;

/// Protocol messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// leader -> worker on join: model dimension, initial model x^0, the
    /// quantizer specs (so both sides build identical codecs), client lr,
    /// and the worker's id.
    Join {
        worker_id: u32,
        d: u32,
        x0: Vec<f32>,
        client_quant: String,
        server_quant: String,
        client_lr: f32,
    },
    /// worker -> leader: one quantized client update (Algorithm 2 line 6).
    Update {
        worker_id: u32,
        /// Server step the worker's replica was at when training started.
        t_start: u64,
        /// Monotone per-worker trip counter (round seed).
        trip: u64,
        train_loss: f32,
        payload: Vec<u8>,
    },
    /// leader -> all workers: broadcast q^t (Algorithm 1 line 13).
    Broadcast { t: u64, absolute: bool, payload: Vec<u8> },
    /// leader -> workers: training is over; report and exit.
    Shutdown,
    /// worker -> leader: goodbye (uploads/bytes accounting echo).
    Bye { worker_id: u32, uploads: u64 },
    /// worker -> leader, first frame on a v2 connection: protocol
    /// version and the worker's requested upload codec — either a
    /// device-tier name the leader resolves against
    /// `scenario.tiers.<name>.quant_client`, or an explicit spec
    /// (`--quant-client`, which wins over the tier). Both `None` means
    /// the default `quant.client` codec. `bandwidth_hint` is the
    /// worker's advertised uplink bandwidth in Mbps; the adaptive
    /// controller (`net.adaptive`) uses it to rank workers when picking
    /// per-worker codecs under the byte budget. A hint-less `Hello`
    /// encodes byte-identically to the pre-hint layout (its own wire
    /// tag), so old leaders and old goldens are untouched.
    Hello {
        version: u8,
        tier: Option<String>,
        quant_client: Option<String>,
        bandwidth_hint: Option<f32>,
    },
    /// leader -> worker, v2 reply to `Hello`: everything [`Message::Join`]
    /// carries, plus the negotiated protocol version and the id of the
    /// worker's upload codec in the leader's registry. `client_quant` is
    /// the *resolved* per-worker spec (tier preset or override, already
    /// normalized per algorithm), not the global default. Likewise
    /// `server_quant` is the worker's *resolved downlink* spec — the
    /// tier's `quant_server` preset when one exists, the global default
    /// otherwise — and `server_codec_id` is that codec's id in the
    /// leader's downlink-family registry
    /// ([`crate::coordinator::Server::register_server_codec`]), so every
    /// `Broadcast` frame the worker receives was encoded by exactly
    /// that codec against that family's hidden state.
    JoinV2 {
        version: u8,
        worker_id: u32,
        d: u32,
        x0: Vec<f32>,
        client_quant: String,
        server_quant: String,
        client_lr: f32,
        codec_id: u32,
        server_codec_id: u32,
    },
    /// worker -> leader, v2 upload: [`Message::Update`] plus the codec
    /// registry id the payload was encoded with.
    UpdateV2 {
        worker_id: u32,
        t_start: u64,
        trip: u64,
        train_loss: f32,
        codec_id: u32,
        payload: Vec<u8>,
    },
    /// edge leader -> upstream leader, v2: a count-weighted partial
    /// aggregate (the tree-of-leaders upload,
    /// [`crate::coordinator::PartialAggregate`] on the wire). The
    /// payload is the edge's buffer encoded with the partial codec at
    /// registry id `codec_id` on the receiver; `count` is how many
    /// client updates it folds; the `stale_*` fields are the serialized
    /// staleness histogram over those updates (weights were already
    /// applied at the edge).
    UpdatePartial {
        worker_id: u32,
        codec_id: u32,
        count: u32,
        stale_counts: Vec<u64>,
        stale_sum: u64,
        stale_max: u64,
        stale_n: u64,
        payload: Vec<u8>,
    },
    /// leader -> worker, v2 only: switch the worker's *upload* codec
    /// mid-run (adaptive quantization control, `net.adaptive`). `spec`
    /// is the resolved codec spec and `codec_id` its id in the leader's
    /// registry (deduped by resolved name, so repeated rekeys between
    /// the same specs never grow the registry); `t` is the server step
    /// the controller issued the switch at. The worker swaps codecs at
    /// its next round boundary and tags subsequent `UpdateV2` frames
    /// with the new id; the leader keeps accepting frames tagged with
    /// the old id until the first new-id upload lands (the transition
    /// window). v1 peers never see this frame.
    Rekey { worker_id: u32, codec_id: u32, spec: String, t: u64 },
    /// leader -> worker: a full-state resynchronization. Sent when a
    /// budgeted writer queue skipped broadcasts for this worker and the
    /// server's [`crate::coordinator::UpdateLog`] has already evicted
    /// the increments the worker would need
    /// ([`crate::coordinator::CatchUp::FullState`]) — the worker
    /// replaces its hidden replica with `x` at step `t`
    /// ([`crate::coordinator::client::HiddenReplica::resync`]) instead
    /// of replaying deltas.
    Sync { t: u64, x: Vec<f32> },
}

const TAG_JOIN: u8 = 1;
const TAG_UPDATE: u8 = 2;
const TAG_BROADCAST: u8 = 3;
const TAG_SHUTDOWN: u8 = 4;
const TAG_BYE: u8 = 5;
const TAG_HELLO: u8 = 6;
const TAG_JOIN2: u8 = 7;
const TAG_UPDATE2: u8 = 8;
const TAG_UPDATE_PARTIAL: u8 = 9;
const TAG_SYNC: u8 = 10;
const TAG_REKEY: u8 = 11;
// A Hello carrying a bandwidth hint gets its own tag: appending a
// trailing optional field to TAG_HELLO would make a cut-before-the-hint
// prefix decode as a valid hint-less Hello, breaking the
// every-strict-prefix-fails property (and the hint-less layout must stay
// byte-identical to the pre-hint contract).
const TAG_HELLO_HINT: u8 = 12;

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new(tag: u8) -> Writer {
        Writer { buf: vec![tag] }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }
    fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }
    fn opt_str(&mut self, v: &Option<String>) {
        match v {
            Some(s) => {
                self.u8(1);
                self.str(s);
            }
            None => self.u8(0),
        }
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn u64s(&mut self, v: &[u64]) {
        self.u32(v.len() as u32);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated frame");
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }
    fn str(&mut self) -> Result<String> {
        String::from_utf8(self.bytes()?).map_err(|e| anyhow!("bad utf8: {e}"))
    }
    fn opt_str(&mut self) -> Result<Option<String>> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.str()?)),
            b => bail!("bad option tag {b} (want 0 or 1)"),
        }
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 8)?;
        Ok(raw.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("trailing bytes in frame");
        }
        Ok(())
    }
}

/// A `Hello`/`JoinV2` version field below 2 is malformed: v1 peers do
/// not have those frames at all, so a versioned frame claiming v1 can
/// only come from a corrupt or confused peer.
fn check_version(v: u8, frame: &str) -> Result<u8> {
    if v < 2 {
        bail!("{frame} frame claims protocol version {v}, but versioned frames start at v2 \
               (a v1 peer never sends {frame})");
    }
    Ok(v)
}

impl Message {
    /// Serialize to a frame body (the transport adds the length prefix).
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Message::Join { worker_id, d, x0, client_quant, server_quant, client_lr } => {
                let mut w = Writer::new(TAG_JOIN);
                w.u32(*worker_id);
                w.u32(*d);
                w.f32s(x0);
                w.str(client_quant);
                w.str(server_quant);
                w.f32(*client_lr);
                w.buf
            }
            Message::Update { worker_id, t_start, trip, train_loss, payload } => {
                let mut w = Writer::new(TAG_UPDATE);
                w.u32(*worker_id);
                w.u64(*t_start);
                w.u64(*trip);
                w.f32(*train_loss);
                w.bytes(payload);
                w.buf
            }
            Message::Broadcast { t, absolute, payload } => {
                let mut w = Writer::new(TAG_BROADCAST);
                w.u64(*t);
                w.buf.push(*absolute as u8);
                w.bytes(payload);
                w.buf
            }
            Message::Shutdown => Writer::new(TAG_SHUTDOWN).buf,
            Message::Bye { worker_id, uploads } => {
                let mut w = Writer::new(TAG_BYE);
                w.u32(*worker_id);
                w.u64(*uploads);
                w.buf
            }
            Message::Hello { version, tier, quant_client, bandwidth_hint } => {
                // hint-less Hello keeps the original tag and byte layout
                let mut w =
                    Writer::new(if bandwidth_hint.is_some() { TAG_HELLO_HINT } else { TAG_HELLO });
                w.u8(*version);
                w.opt_str(tier);
                w.opt_str(quant_client);
                if let Some(mbps) = bandwidth_hint {
                    w.f32(*mbps);
                }
                w.buf
            }
            Message::Rekey { worker_id, codec_id, spec, t } => {
                let mut w = Writer::new(TAG_REKEY);
                w.u32(*worker_id);
                w.u32(*codec_id);
                w.str(spec);
                w.u64(*t);
                w.buf
            }
            Message::JoinV2 {
                version,
                worker_id,
                d,
                x0,
                client_quant,
                server_quant,
                client_lr,
                codec_id,
                server_codec_id,
            } => {
                let mut w = Writer::new(TAG_JOIN2);
                w.u8(*version);
                w.u32(*worker_id);
                w.u32(*d);
                w.f32s(x0);
                w.str(client_quant);
                w.str(server_quant);
                w.f32(*client_lr);
                w.u32(*codec_id);
                w.u32(*server_codec_id);
                w.buf
            }
            Message::UpdateV2 { worker_id, t_start, trip, train_loss, codec_id, payload } => {
                let mut w = Writer::new(TAG_UPDATE2);
                w.u32(*worker_id);
                w.u64(*t_start);
                w.u64(*trip);
                w.f32(*train_loss);
                w.u32(*codec_id);
                w.bytes(payload);
                w.buf
            }
            Message::UpdatePartial {
                worker_id,
                codec_id,
                count,
                stale_counts,
                stale_sum,
                stale_max,
                stale_n,
                payload,
            } => {
                let mut w = Writer::new(TAG_UPDATE_PARTIAL);
                w.u32(*worker_id);
                w.u32(*codec_id);
                w.u32(*count);
                w.u64s(stale_counts);
                w.u64(*stale_sum);
                w.u64(*stale_max);
                w.u64(*stale_n);
                w.bytes(payload);
                w.buf
            }
            Message::Sync { t, x } => {
                let mut w = Writer::new(TAG_SYNC);
                w.u64(*t);
                w.f32s(x);
                w.buf
            }
        }
    }

    pub fn decode(frame: &[u8]) -> Result<Message> {
        let mut r = Reader::new(frame);
        let msg = match r.u8()? {
            TAG_JOIN => Message::Join {
                worker_id: r.u32()?,
                d: r.u32()?,
                x0: r.f32s()?,
                client_quant: r.str()?,
                server_quant: r.str()?,
                client_lr: r.f32()?,
            },
            TAG_UPDATE => Message::Update {
                worker_id: r.u32()?,
                t_start: r.u64()?,
                trip: r.u64()?,
                train_loss: r.f32()?,
                payload: r.bytes()?,
            },
            TAG_BROADCAST => Message::Broadcast {
                t: r.u64()?,
                absolute: r.u8()? != 0,
                payload: r.bytes()?,
            },
            TAG_SHUTDOWN => Message::Shutdown,
            TAG_BYE => Message::Bye { worker_id: r.u32()?, uploads: r.u64()? },
            TAG_HELLO => Message::Hello {
                version: check_version(r.u8()?, "Hello")?,
                tier: r.opt_str()?,
                quant_client: r.opt_str()?,
                bandwidth_hint: None,
            },
            TAG_HELLO_HINT => Message::Hello {
                version: check_version(r.u8()?, "Hello")?,
                tier: r.opt_str()?,
                quant_client: r.opt_str()?,
                bandwidth_hint: Some(r.f32()?),
            },
            TAG_REKEY => Message::Rekey {
                worker_id: r.u32()?,
                codec_id: r.u32()?,
                spec: r.str()?,
                t: r.u64()?,
            },
            TAG_JOIN2 => Message::JoinV2 {
                version: check_version(r.u8()?, "JoinV2")?,
                worker_id: r.u32()?,
                d: r.u32()?,
                x0: r.f32s()?,
                client_quant: r.str()?,
                server_quant: r.str()?,
                client_lr: r.f32()?,
                codec_id: r.u32()?,
                server_codec_id: r.u32()?,
            },
            TAG_UPDATE2 => Message::UpdateV2 {
                worker_id: r.u32()?,
                t_start: r.u64()?,
                trip: r.u64()?,
                train_loss: r.f32()?,
                codec_id: r.u32()?,
                payload: r.bytes()?,
            },
            TAG_UPDATE_PARTIAL => Message::UpdatePartial {
                worker_id: r.u32()?,
                codec_id: r.u32()?,
                count: r.u32()?,
                stale_counts: r.u64s()?,
                stale_sum: r.u64()?,
                stale_max: r.u64()?,
                stale_n: r.u64()?,
                payload: r.bytes()?,
            },
            TAG_SYNC => Message::Sync { t: r.u64()?, x: r.f32s()? },
            tag => bail!("unknown message tag {tag}"),
        };
        r.done()?;
        Ok(msg)
    }

    /// Wrap a quantized payload for a v1 upload.
    pub fn update_from(
        worker_id: u32,
        t_start: u64,
        trip: u64,
        train_loss: f32,
        msg: &QuantizedMsg,
    ) -> Message {
        Message::Update { worker_id, t_start, trip, train_loss, payload: msg.payload.clone() }
    }

    /// Wrap a quantized payload for a v2 upload tagged with its codec id.
    pub fn update_v2_from(
        worker_id: u32,
        t_start: u64,
        trip: u64,
        train_loss: f32,
        codec_id: u32,
        msg: &QuantizedMsg,
    ) -> Message {
        Message::UpdateV2 {
            worker_id,
            t_start,
            trip,
            train_loss,
            codec_id,
            payload: msg.payload.clone(),
        }
    }

    /// Wrap a partial aggregate for an edge-leader upload, serializing
    /// its staleness histogram field by field.
    pub fn update_partial_from(
        worker_id: u32,
        codec_id: u32,
        partial: &crate::coordinator::PartialAggregate,
    ) -> Message {
        Message::UpdatePartial {
            worker_id,
            codec_id,
            count: partial.count,
            stale_counts: partial.staleness.counts.clone(),
            stale_sum: partial.staleness.sum,
            stale_max: partial.staleness.max,
            stale_n: partial.staleness.n,
            payload: partial.msg.payload.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One instance of every variant, exercising both `None` and `Some`
    /// option fields, empty and non-empty payloads, and non-ascii text.
    fn all_variants() -> Vec<Message> {
        vec![
            Message::Join {
                worker_id: 3,
                d: 4,
                x0: vec![1.0, -2.0, 0.5, 0.0],
                client_quant: "qsgd:4".into(),
                server_quant: "top:0.1".into(),
                client_lr: 4.7e-6,
            },
            Message::Update {
                worker_id: 1,
                t_start: 17,
                trip: 99,
                train_loss: 0.25,
                payload: vec![1, 2, 3, 255],
            },
            Message::Update { worker_id: 0, t_start: 0, trip: 0, train_loss: 0.0, payload: vec![] },
            Message::Broadcast { t: 5, absolute: true, payload: vec![9; 100] },
            Message::Broadcast { t: u64::MAX, absolute: false, payload: vec![] },
            Message::Shutdown,
            Message::Bye { worker_id: 2, uploads: 41 },
            Message::Hello { version: 2, tier: None, quant_client: None, bandwidth_hint: None },
            Message::Hello {
                version: 2,
                tier: Some("phone".into()),
                quant_client: None,
                bandwidth_hint: None,
            },
            Message::Hello {
                version: 7,
                tier: Some("tier-β".into()),
                quant_client: Some("top:0.1".into()),
                bandwidth_hint: None,
            },
            Message::Hello {
                version: 2,
                tier: None,
                quant_client: None,
                bandwidth_hint: Some(2.5),
            },
            Message::Hello {
                version: 2,
                tier: Some("phone".into()),
                quant_client: Some("qsgd:4".into()),
                bandwidth_hint: Some(0.125),
            },
            Message::Rekey { worker_id: 3, codec_id: 2, spec: "qsgd:4".into(), t: 40 },
            Message::Rekey { worker_id: 0, codec_id: 0, spec: "".into(), t: 0 },
            Message::JoinV2 {
                version: 2,
                worker_id: 9,
                d: 2,
                x0: vec![0.5, -0.5],
                client_quant: "qsgd:8".into(),
                server_quant: "qsgd:4".into(),
                client_lr: 0.05,
                codec_id: 3,
                server_codec_id: 1,
            },
            Message::UpdateV2 {
                worker_id: 4,
                t_start: 8,
                trip: 12,
                train_loss: 1.5,
                codec_id: 2,
                payload: vec![0, 128, 255],
            },
            Message::UpdateV2 {
                worker_id: 0,
                t_start: 0,
                trip: 0,
                train_loss: 0.0,
                codec_id: 0,
                payload: vec![],
            },
            Message::UpdatePartial {
                worker_id: 6,
                codec_id: 1,
                count: 4,
                stale_counts: vec![2, 1, 1],
                stale_sum: 5,
                stale_max: 3,
                stale_n: 4,
                payload: vec![7, 0, 255, 1],
            },
            Message::UpdatePartial {
                worker_id: 0,
                codec_id: 0,
                count: 0,
                stale_counts: vec![],
                stale_sum: 0,
                stale_max: 0,
                stale_n: 0,
                payload: vec![],
            },
            Message::Sync { t: 12, x: vec![0.25, -1.5, 3.0] },
            Message::Sync { t: 0, x: vec![] },
        ]
    }

    #[test]
    fn roundtrip_all_variants() {
        for m in all_variants() {
            let enc = m.encode();
            let dec = Message::decode(&enc).unwrap();
            assert_eq!(m, dec);
        }
    }

    #[test]
    fn every_strict_prefix_fails_to_decode() {
        // Each field is either fixed-width or length-prefixed and decode
        // demands exact consumption, so no strict prefix of a valid
        // frame may itself decode (a truncated frame can never be
        // silently mistaken for a shorter valid message).
        for m in all_variants() {
            let enc = m.encode();
            for cut in 0..enc.len() {
                assert!(
                    Message::decode(&enc[..cut]).is_err(),
                    "{m:?}: prefix of {cut}/{} bytes decoded",
                    enc.len()
                );
            }
        }
    }

    #[test]
    fn trailing_bytes_rejected_for_all_variants() {
        for m in all_variants() {
            let mut enc = m.encode();
            enc.push(0);
            assert!(Message::decode(&enc).is_err(), "{m:?}: trailing byte accepted");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[42]).is_err()); // unknown tag
        assert!(Message::decode(&[0]).is_err()); // tag 0 is reserved
        // bad option-presence byte in Hello (must be 0 or 1)
        let mut hello =
            Message::Hello { version: 2, tier: None, quant_client: None, bandwidth_hint: None }
                .encode();
        hello[2] = 9;
        assert!(Message::decode(&hello).is_err());
        // same poke on the hint-carrying layout
        let mut hello = Message::Hello {
            version: 2,
            tier: None,
            quant_client: None,
            bandwidth_hint: Some(1.0),
        }
        .encode();
        hello[2] = 9;
        assert!(Message::decode(&hello).is_err());
        // bad utf8 inside a Join string
        let mut join = Message::Join {
            worker_id: 0,
            d: 1,
            x0: vec![0.0],
            client_quant: "none".into(),
            server_quant: "none".into(),
            client_lr: 0.1,
        }
        .encode();
        let s = join.len() - 4 - 4; // start of "none" (server_quant)
        join[s] = 0xFF;
        assert!(Message::decode(&join).is_err());
    }

    #[test]
    fn version_below_2_rejected_in_versioned_frames() {
        // A v1 peer never emits Hello/JoinV2, so a version field of 0 or
        // 1 is a protocol confusion and must fail at decode time.
        for v in [0u8, 1] {
            let mut hello =
                Message::Hello { version: 2, tier: None, quant_client: None, bandwidth_hint: None }
                    .encode();
            hello[1] = v;
            let err = Message::decode(&hello).unwrap_err().to_string();
            assert!(err.contains("version"), "{err}");
            // the hint-carrying layout runs the same version gate
            let mut hinted = Message::Hello {
                version: 2,
                tier: None,
                quant_client: None,
                bandwidth_hint: Some(8.0),
            }
            .encode();
            hinted[1] = v;
            assert!(Message::decode(&hinted).is_err());
            let mut join = Message::JoinV2 {
                version: 2,
                worker_id: 0,
                d: 1,
                x0: vec![0.0],
                client_quant: "none".into(),
                server_quant: "none".into(),
                client_lr: 0.1,
                codec_id: 0,
                server_codec_id: 0,
            }
            .encode();
            join[1] = v;
            assert!(Message::decode(&join).is_err());
        }
        // future versions decode fine (the connection then runs at the
        // minimum of the two ends' versions)
        let hello =
            Message::Hello { version: 9, tier: None, quant_client: None, bandwidth_hint: None };
        assert_eq!(Message::decode(&hello.encode()).unwrap(), hello);
    }

    #[test]
    fn hintless_hello_layout_pinned_byte_for_byte() {
        // The hint-less Hello is the v2 handshake contract from the
        // codec-negotiation PR: adding the bandwidth hint must not move
        // a single byte of it (old leaders keep decoding new workers
        // that send no hint).
        let hello = Message::Hello {
            version: 2,
            tier: Some("phone".into()),
            quant_client: None,
            bandwidth_hint: None,
        };
        let mut expect = vec![6u8]; // TAG_HELLO, unchanged
        expect.push(2); // version
        expect.push(1); // tier present
        expect.extend_from_slice(&5u32.to_le_bytes());
        expect.extend_from_slice(b"phone");
        expect.push(0); // quant_client absent
        assert_eq!(hello.encode(), expect);

        // the hint rides under its own tag, after the same fields
        let hinted = Message::Hello {
            version: 2,
            tier: Some("phone".into()),
            quant_client: None,
            bandwidth_hint: Some(2.5),
        };
        let mut expect_hint = vec![12u8]; // TAG_HELLO_HINT
        expect_hint.extend_from_slice(&expect[1..]);
        expect_hint.extend_from_slice(&2.5f32.to_le_bytes());
        assert_eq!(hinted.encode(), expect_hint);
    }

    #[test]
    fn rekey_layout_pinned_byte_for_byte() {
        let rekey = Message::Rekey { worker_id: 9, codec_id: 3, spec: "qsgd:4".into(), t: 17 };
        let mut expect = vec![11u8]; // TAG_REKEY
        expect.extend_from_slice(&9u32.to_le_bytes());
        expect.extend_from_slice(&3u32.to_le_bytes());
        expect.extend_from_slice(&6u32.to_le_bytes());
        expect.extend_from_slice(b"qsgd:4");
        expect.extend_from_slice(&17u64.to_le_bytes());
        assert_eq!(rekey.encode(), expect);
        assert_eq!(Message::decode(&expect).unwrap(), rekey);
        // bad utf8 in the spec string is rejected
        let spec_start = 1 + 4 + 4 + 4;
        let mut bad = expect.clone();
        bad[spec_start] = 0xFF;
        assert!(Message::decode(&bad).is_err());
    }

    #[test]
    fn v1_frame_layout_pinned_byte_for_byte() {
        // The v1 wire layout is a compatibility contract: these bytes
        // must never change. Built by hand, field by field.
        let join = Message::Join {
            worker_id: 7,
            d: 2,
            x0: vec![1.5, -0.25],
            client_quant: "qsgd:4".into(),
            server_quant: "none".into(),
            client_lr: 0.5,
        };
        let mut expect = vec![1u8]; // TAG_JOIN
        expect.extend_from_slice(&7u32.to_le_bytes());
        expect.extend_from_slice(&2u32.to_le_bytes());
        expect.extend_from_slice(&2u32.to_le_bytes()); // x0 length
        expect.extend_from_slice(&1.5f32.to_le_bytes());
        expect.extend_from_slice(&(-0.25f32).to_le_bytes());
        expect.extend_from_slice(&6u32.to_le_bytes());
        expect.extend_from_slice(b"qsgd:4");
        expect.extend_from_slice(&4u32.to_le_bytes());
        expect.extend_from_slice(b"none");
        expect.extend_from_slice(&0.5f32.to_le_bytes());
        assert_eq!(join.encode(), expect);

        let update = Message::Update {
            worker_id: 3,
            t_start: 10,
            trip: 4,
            train_loss: 0.75,
            payload: vec![0xAB, 0xCD],
        };
        let mut expect = vec![2u8]; // TAG_UPDATE
        expect.extend_from_slice(&3u32.to_le_bytes());
        expect.extend_from_slice(&10u64.to_le_bytes());
        expect.extend_from_slice(&4u64.to_le_bytes());
        expect.extend_from_slice(&0.75f32.to_le_bytes());
        expect.extend_from_slice(&2u32.to_le_bytes());
        expect.extend_from_slice(&[0xAB, 0xCD]);
        assert_eq!(update.encode(), expect);

        let bcast = Message::Broadcast { t: 6, absolute: true, payload: vec![0x11] };
        let mut expect = vec![3u8]; // TAG_BROADCAST
        expect.extend_from_slice(&6u64.to_le_bytes());
        expect.push(1);
        expect.extend_from_slice(&1u32.to_le_bytes());
        expect.push(0x11);
        assert_eq!(bcast.encode(), expect);

        assert_eq!(Message::Shutdown.encode(), vec![4u8]);

        let bye = Message::Bye { worker_id: 1, uploads: 9 };
        let mut expect = vec![5u8]; // TAG_BYE
        expect.extend_from_slice(&1u32.to_le_bytes());
        expect.extend_from_slice(&9u64.to_le_bytes());
        assert_eq!(bye.encode(), expect);
    }

    #[test]
    fn update_wrappers_carry_the_payload() {
        let qmsg = QuantizedMsg { payload: vec![1, 2, 3], d: 3 };
        match Message::update_from(5, 1, 2, 0.5, &qmsg) {
            Message::Update { worker_id, payload, .. } => {
                assert_eq!(worker_id, 5);
                assert_eq!(payload, vec![1, 2, 3]);
            }
            other => panic!("unexpected {other:?}"),
        }
        match Message::update_v2_from(5, 1, 2, 0.5, 7, &qmsg) {
            Message::UpdateV2 { codec_id, payload, .. } => {
                assert_eq!(codec_id, 7);
                assert_eq!(payload, vec![1, 2, 3]);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn partial_aggregate_survives_the_wire() {
        use crate::coordinator::PartialAggregate;
        use crate::scenario::metrics::StalenessHist;
        let mut hist = StalenessHist::default();
        for s in [0u64, 2, 2, 7] {
            hist.record(s);
        }
        let partial = PartialAggregate {
            msg: QuantizedMsg { payload: vec![9, 8, 7, 6], d: 1 },
            count: 4,
            staleness: hist.clone(),
        };
        let frame = Message::update_partial_from(11, 1, &partial);
        let decoded = Message::decode(&frame.encode()).unwrap();
        match decoded {
            Message::UpdatePartial {
                worker_id,
                codec_id,
                count,
                stale_counts,
                stale_sum,
                stale_max,
                stale_n,
                payload,
            } => {
                assert_eq!((worker_id, codec_id, count), (11, 1, 4));
                assert_eq!(payload, vec![9, 8, 7, 6]);
                // the histogram reassembles exactly on the far side
                let rebuilt =
                    StalenessHist::from_parts(stale_counts, stale_sum, stale_max, stale_n);
                assert_eq!(rebuilt, hist);
            }
            other => panic!("unexpected {other:?}"),
        }
    }
}
