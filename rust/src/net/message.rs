//! Wire protocol: length-prefixed binary frames, hand-rolled codec (no
//! serde offline). All multi-byte integers are little-endian.

use crate::quant::QuantizedMsg;
use anyhow::{anyhow, bail, Result};

/// Protocol messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// leader -> worker on join: model dimension, initial model x^0, the
    /// quantizer specs (so both sides build identical codecs), client lr,
    /// and the worker's id.
    Join {
        worker_id: u32,
        d: u32,
        x0: Vec<f32>,
        client_quant: String,
        server_quant: String,
        client_lr: f32,
    },
    /// worker -> leader: one quantized client update (Algorithm 2 line 6).
    Update {
        worker_id: u32,
        /// Server step the worker's replica was at when training started.
        t_start: u64,
        /// Monotone per-worker trip counter (round seed).
        trip: u64,
        train_loss: f32,
        payload: Vec<u8>,
    },
    /// leader -> all workers: broadcast q^t (Algorithm 1 line 13).
    Broadcast { t: u64, absolute: bool, payload: Vec<u8> },
    /// leader -> workers: training is over; report and exit.
    Shutdown,
    /// worker -> leader: goodbye (uploads/bytes accounting echo).
    Bye { worker_id: u32, uploads: u64 },
}

const TAG_JOIN: u8 = 1;
const TAG_UPDATE: u8 = 2;
const TAG_BROADCAST: u8 = 3;
const TAG_SHUTDOWN: u8 = 4;
const TAG_BYE: u8 = 5;

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new(tag: u8) -> Writer {
        Writer { buf: vec![tag] }
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }
    fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated frame");
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }
    fn str(&mut self) -> Result<String> {
        String::from_utf8(self.bytes()?).map_err(|e| anyhow!("bad utf8: {e}"))
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("trailing bytes in frame");
        }
        Ok(())
    }
}

impl Message {
    /// Serialize to a frame body (the transport adds the length prefix).
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Message::Join { worker_id, d, x0, client_quant, server_quant, client_lr } => {
                let mut w = Writer::new(TAG_JOIN);
                w.u32(*worker_id);
                w.u32(*d);
                w.f32s(x0);
                w.str(client_quant);
                w.str(server_quant);
                w.f32(*client_lr);
                w.buf
            }
            Message::Update { worker_id, t_start, trip, train_loss, payload } => {
                let mut w = Writer::new(TAG_UPDATE);
                w.u32(*worker_id);
                w.u64(*t_start);
                w.u64(*trip);
                w.f32(*train_loss);
                w.bytes(payload);
                w.buf
            }
            Message::Broadcast { t, absolute, payload } => {
                let mut w = Writer::new(TAG_BROADCAST);
                w.u64(*t);
                w.buf.push(*absolute as u8);
                w.bytes(payload);
                w.buf
            }
            Message::Shutdown => Writer::new(TAG_SHUTDOWN).buf,
            Message::Bye { worker_id, uploads } => {
                let mut w = Writer::new(TAG_BYE);
                w.u32(*worker_id);
                w.u64(*uploads);
                w.buf
            }
        }
    }

    pub fn decode(frame: &[u8]) -> Result<Message> {
        let mut r = Reader::new(frame);
        let msg = match r.u8()? {
            TAG_JOIN => Message::Join {
                worker_id: r.u32()?,
                d: r.u32()?,
                x0: r.f32s()?,
                client_quant: r.str()?,
                server_quant: r.str()?,
                client_lr: r.f32()?,
            },
            TAG_UPDATE => Message::Update {
                worker_id: r.u32()?,
                t_start: r.u64()?,
                trip: r.u64()?,
                train_loss: r.f32()?,
                payload: r.bytes()?,
            },
            TAG_BROADCAST => Message::Broadcast {
                t: r.u64()?,
                absolute: r.u8()? != 0,
                payload: r.bytes()?,
            },
            TAG_SHUTDOWN => Message::Shutdown,
            TAG_BYE => Message::Bye { worker_id: r.u32()?, uploads: r.u64()? },
            tag => bail!("unknown message tag {tag}"),
        };
        r.done()?;
        Ok(msg)
    }

    /// Wrap a quantized payload for upload.
    pub fn update_from(
        worker_id: u32,
        t_start: u64,
        trip: u64,
        train_loss: f32,
        msg: &QuantizedMsg,
    ) -> Message {
        Message::Update { worker_id, t_start, trip, train_loss, payload: msg.payload.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_variants() {
        let msgs = vec![
            Message::Join {
                worker_id: 3,
                d: 4,
                x0: vec![1.0, -2.0, 0.5, 0.0],
                client_quant: "qsgd:4".into(),
                server_quant: "top:0.1".into(),
                client_lr: 4.7e-6,
            },
            Message::Update {
                worker_id: 1,
                t_start: 17,
                trip: 99,
                train_loss: 0.25,
                payload: vec![1, 2, 3, 255],
            },
            Message::Broadcast { t: 5, absolute: true, payload: vec![9; 100] },
            Message::Shutdown,
            Message::Bye { worker_id: 2, uploads: 41 },
        ];
        for m in msgs {
            let enc = m.encode();
            let dec = Message::decode(&enc).unwrap();
            assert_eq!(m, dec);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[42]).is_err());
        // truncated Join
        let good = Message::Join {
            worker_id: 0,
            d: 1,
            x0: vec![0.0],
            client_quant: "none".into(),
            server_quant: "none".into(),
            client_lr: 0.1,
        }
        .encode();
        assert!(Message::decode(&good[..good.len() - 2]).is_err());
        // trailing bytes
        let mut padded = good;
        padded.push(0);
        assert!(Message::decode(&padded).is_err());
    }
}
