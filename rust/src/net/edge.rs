//! The edge-leader process: an interior node of the aggregation tree.
//!
//! An edge leader is **simultaneously a v2 worker upstream and a leader
//! downstream** (ISSUE 6 / ARCHITECTURE.md §Aggregator tree). Upstream
//! it opens with the same `Hello` any v2 worker sends and receives a
//! `JoinV2` carrying the model dimension, x^0 and the quantizer specs;
//! downstream it accepts v2 workers exactly like the root [`super::Leader`]
//! (per-worker codec negotiation, one reader + one persistent writer
//! thread per connection, `Arc<[u8]>` broadcast fan-out).
//!
//! The node itself is **model-free**: it owns an
//! [`EdgeAggregator`] — a buffer of size `net.edge_buffer` plus the
//! `net.partial_codec` `Q_p` — and forwards a count-weighted
//! [`crate::coordinator::PartialAggregate`] upstream (an `UpdatePartial`
//! frame, tag 9) every time the buffer fills. Broadcasts are relayed
//! downstream byte-identically without being decoded; the edge only
//! tracks the step counter `replica_t` to gap-check the stream and to
//! timestamp staleness for its own workers' uploads. Staleness is
//! therefore measured against the edge's replica clock — the same
//! `t_start`-based convention the flat leader uses, observed one hop
//! earlier; the histogram travels upstream inside the partial and is
//! merged into the root's accounting.
//!
//! **Budgeted fan-out** (ISSUE 8): with `net.broadcast_budget_bytes >
//! 0` the downstream writer queues are bounded [`FrameQueue`]s, and the
//! edge gives up being model-free — it keeps a
//! [`HiddenReplica`] of the relayed stream so that when a slow
//! downstream worker's queue evicts frames, the writer can fold the
//! gap into one full-state `Sync` (the edge has no
//! [`crate::coordinator::UpdateLog`], so every fold is a full sync —
//! bounded by one model, per Appendix B.1). Upstream `Sync` frames
//! (the root folding for a slow *edge*) are relayed downstream as
//! never-evicted control frames. The edge relays a single downlink
//! family — its own, negotiated upstream; per-tier downlink *below* an
//! edge is out of scope (downstream tiers still resolve per-tier
//! *upload* codecs).
//!
//! Edge leaders are v2-only downstream: a silent (v1) worker fails the
//! handshake loudly instead of being served legacy frames.

use super::leader::{CodecEpoch, WorkerStats};
use super::message::{Message, PROTOCOL_VERSION};
use super::queue::{FrameQueue, QueuedFrame};
use super::transport::{frame_bytes, read_msg, read_msg_classified, write_msg, Conn, ReadOutcome};
use crate::config::Config;
use crate::coordinator::client::HiddenReplica;
use crate::coordinator::{AggOutcome, Broadcast, EdgeAggregator};
use crate::quant::QuantizedMsg;
use crate::scenario::StalenessHist;
use crate::util::pool::ShardPool;
use anyhow::{anyhow, bail, Context, Result};
use std::io::Write;
use std::net::TcpListener;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

/// Synthetic "worker id" for messages arriving from upstream on the
/// shared fan-in channel (real downstream ids are 0..n_workers).
const UPSTREAM: u32 = u32::MAX;

/// Final report of an edge-leader run.
#[derive(Clone, Debug)]
pub struct EdgeReport {
    /// The worker id the upstream leader assigned this edge.
    pub edge_worker_id: u32,
    pub d: usize,
    /// Client updates ingested from downstream workers.
    pub updates: u64,
    /// Wire bytes of those updates.
    pub update_bytes: u64,
    /// Partial aggregates forwarded upstream.
    pub partials: u64,
    /// Wire bytes of those partials (payload, as framed).
    pub partial_bytes: u64,
    /// Updates still sitting in the buffer when shutdown arrived; they
    /// are dropped, exactly like a flat worker's in-flight upload that
    /// lands after the root's shutdown.
    pub pending_at_shutdown: usize,
    /// Final replica step (how far the relayed broadcast stream got).
    pub replica_t: u64,
    /// Resolved spec name of `Q_p`.
    pub partial_codec: String,
    /// Staleness histogram over every ingested downstream update.
    pub staleness: StalenessHist,
    /// Per-downstream-worker accounting (same shape as the root's).
    pub worker_stats: Vec<WorkerStats>,
}

/// Edge-leader configuration + run loop.
pub struct EdgeLeader {
    cfg: Config,
    /// Seeds `Q_p`'s quantization noise (`Prng::new(seed)` →
    /// `"edge-quant"` stream inside [`EdgeAggregator`]).
    seed: u64,
}

impl EdgeLeader {
    pub fn new(cfg: Config, seed: u64) -> EdgeLeader {
        EdgeLeader { cfg, seed }
    }

    /// Connect to the upstream leader at `upstream`, serve downstream
    /// workers on `addr`, and run until the upstream shuts the tree down.
    pub fn run(&self, upstream: &str, addr: &str, n_workers: usize) -> Result<EdgeReport> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        self.run_on(listener, upstream, n_workers)
    }

    /// Like [`EdgeLeader::run`] with a pre-bound listener (tests use an
    /// ephemeral port).
    pub fn run_on(
        &self,
        listener: TcpListener,
        upstream: &str,
        n_workers: usize,
    ) -> Result<EdgeReport> {
        // --- join upstream as a plain v2 worker -------------------------
        // The Hello carries no tier/quant_client: the edge never uploads
        // client-codec frames, only UpdatePartial frames decoded through
        // the root's partial-codec registry (config-ordered, id 0).
        let mut up = Conn::connect(upstream)?;
        up.send(&Message::Hello {
            version: PROTOCOL_VERSION,
            tier: None,
            quant_client: None,
            bandwidth_hint: None,
        })
        .context("sending Hello upstream")?;
        let (edge_worker_id, d, x0, server_quant, client_lr, sc_id) = match up
            .recv()
            .context("reading join from upstream")?
        {
            Some(Message::JoinV2 {
                worker_id, d, x0, server_quant, client_lr, server_codec_id, ..
            }) => (worker_id, d as usize, x0, server_quant, client_lr, server_codec_id),
            Some(Message::Join { .. }) => {
                bail!("upstream answered with a v1 Join — edge leaders need a v2 root")
            }
            other => bail!("expected JoinV2 from upstream, got {other:?}"),
        };

        // --- the aggregation node --------------------------------------
        let mut edge = EdgeAggregator::new(
            d,
            self.cfg.net.edge_buffer,
            &self.cfg.net.partial_codec,
            &self.cfg.quant.client,
            self.cfg.fl.algorithm,
            self.cfg.fl.staleness_scaling,
            ShardPool::new(self.cfg.fl.shards.max(1)),
            self.seed,
        )?;
        // same tier-order registration as the root => same codec ids on
        // every node of the tree
        let tiers = self.cfg.resolved_tiers();
        let tier_codecs = edge.register_tier_presets(&self.cfg)?;
        let grace = Duration::from_millis(self.cfg.net.v1_grace_ms.max(1));

        // Budgeted fan-out: the edge keeps its own replica of the
        // relayed stream (decoded with the downlink codec negotiated
        // upstream) so a slow downstream worker's fold can ship an
        // exact full-state Sync. At the default budget 0 the edge
        // stays model-free and never decodes a broadcast.
        let budget = self.cfg.net.broadcast_budget_bytes;
        let edge_replica: Option<Arc<Mutex<HiddenReplica>>> = if budget > 0 {
            Some(Arc::new(Mutex::new(HiddenReplica::with_spec(
                &server_quant,
                x0.clone(),
                ShardPool::new(self.cfg.fl.shards.max(1)),
            )?)))
        } else {
            None
        };

        // --- accept downstream workers (v2-only) -----------------------
        let (tx, rx) = mpsc::channel::<(u32, Result<Option<Message>>)>();
        let mut queues: Vec<Arc<FrameQueue>> = Vec::new();
        let mut writer_handles = Vec::new();
        let mut reader_handles = Vec::new();
        let mut stats: Vec<WorkerStats> = Vec::new();
        for worker_id in 0..n_workers as u32 {
            let (stream, peer) = listener.accept().context("accepting worker")?;
            stream.set_nodelay(true).ok();
            let peer = peer.to_string();
            stream
                .set_read_timeout(Some(grace))
                .with_context(|| format!("worker {worker_id} ({peer}): handshake timeout"))?;
            let mut reader = stream.try_clone().context("cloning tcp stream")?;
            let mut writer = stream;
            let hello = read_msg(&mut reader)
                .with_context(|| {
                    format!(
                        "reading Hello from worker {worker_id} ({peer}) within {}ms — \
                         edge leaders are v2-only (no silent v1 joins)",
                        grace.as_millis()
                    )
                })?
                .ok_or_else(|| {
                    anyhow!("worker {worker_id} ({peer}) disconnected during handshake")
                })?;
            // the bandwidth hint is accepted but unused here: only the
            // root leader runs the adaptive controller, and an edge
            // never forwards Rekey frames downstream
            let (version, tier, quant_client) = match hello {
                Message::Hello { version, tier, quant_client, bandwidth_hint: _ } => {
                    (version, tier, quant_client)
                }
                other => bail!("worker {worker_id} ({peer}): expected Hello, got {other:?}"),
            };
            let version = version.min(PROTOCOL_VERSION);
            // per-worker codec: explicit override > tier preset > default
            let codec_id = if let Some(spec) = quant_client {
                edge.register_client_codec(&spec).with_context(|| {
                    format!("worker {worker_id} ({peer}): bad quant_client '{spec}'")
                })?
            } else if let Some(name) = tier {
                match tiers.iter().position(|t| t.name == name) {
                    Some(i) => tier_codecs[i],
                    None => bail!(
                        "worker {worker_id} ({peer}): unknown tier '{name}' (known: {})",
                        tiers.iter().map(|t| t.name.as_str()).collect::<Vec<_>>().join(", ")
                    ),
                }
            } else {
                0
            };
            // relay the upstream join material: same x^0, same server
            // codec (the edge's own downlink family — per-tier downlink
            // below an edge is out of scope), same client lr everywhere
            // in the tree
            write_msg(
                &mut writer,
                &Message::JoinV2 {
                    version,
                    worker_id,
                    d: d as u32,
                    x0: x0.clone(),
                    client_quant: edge.client_codec_name(codec_id),
                    server_quant: server_quant.clone(),
                    client_lr,
                    codec_id: codec_id as u32,
                    server_codec_id: sc_id,
                },
            )
            .with_context(|| format!("sending JoinV2 to worker {worker_id} ({peer})"))?;
            reader
                .set_read_timeout(None)
                .with_context(|| format!("worker {worker_id} ({peer}): clearing deadline"))?;

            let txc = tx.clone();
            reader_handles.push(std::thread::spawn(move || {
                loop {
                    match read_msg_classified(&mut reader) {
                        ReadOutcome::Msg(msg) => {
                            if txc.send((worker_id, Ok(Some(msg)))).is_err() {
                                break;
                            }
                        }
                        ReadOutcome::Disconnected(_) => {
                            let _ = txc.send((worker_id, Ok(None)));
                            break;
                        }
                        ReadOutcome::BadFrame(e) => {
                            let _ = txc.send((worker_id, Err(e)));
                            break;
                        }
                    }
                }
            }));
            // persistent writer thread on a bounded queue. Under budget
            // pressure a gap is folded into one full-state Sync from
            // the edge replica (updated before any queue push, so the
            // replica always covers the frame that exposed the gap).
            let queue = FrameQueue::new(budget);
            let q = Arc::clone(&queue);
            let sync_src = edge_replica.clone();
            writer_handles.push(std::thread::spawn(move || {
                let mut frames = 0u64;
                let mut bytes = 0u64;
                let mut send_ns = 0u64;
                let mut catch_up_frames = 0u64;
                let mut full_syncs = 0u64;
                // rebased on the first relayed frame (the edge does not
                // know the root's join step)
                let mut last_sent: Option<u64> = None;
                while let Some(item) = q.pop() {
                    let frame: Arc<[u8]> = match item {
                        QueuedFrame::Control(frame) => frame,
                        QueuedFrame::Step { t, frame } => {
                            if let Some(src) = &sync_src {
                                match last_sent {
                                    Some(ls) if t <= ls => continue,
                                    Some(ls) if t > ls + 1 => {
                                        let (st, x) = {
                                            let r = src.lock().unwrap();
                                            (r.t, r.state().to_vec())
                                        };
                                        let Ok(f) = frame_bytes(&Message::Sync { t: st, x })
                                        else {
                                            break;
                                        };
                                        let timer = crate::telemetry::span_start();
                                        if writer.write_all(&f).is_err() {
                                            break;
                                        }
                                        send_ns += crate::telemetry::span_ns(timer);
                                        frames += 1;
                                        bytes += f.len() as u64;
                                        catch_up_frames += 1;
                                        full_syncs += 1;
                                        last_sent = Some(st);
                                        continue;
                                    }
                                    _ => last_sent = Some(t),
                                }
                            }
                            frame
                        }
                    };
                    let timer = crate::telemetry::span_start();
                    if writer.write_all(&frame).is_err() {
                        break;
                    }
                    send_ns += crate::telemetry::span_ns(timer);
                    frames += 1;
                    bytes += frame.len() as u64;
                }
                (frames, bytes, send_ns, catch_up_frames, full_syncs)
            }));
            queues.push(queue);
            stats.push(WorkerStats {
                worker_id,
                peer,
                protocol: version,
                codec_id,
                codec: edge.client_codec_name(codec_id),
                bandwidth_hint: None,
                rekeys: 0,
                epochs: vec![CodecEpoch {
                    codec_id,
                    codec: edge.client_codec_name(codec_id),
                    uploads: 0,
                    upload_bytes: 0,
                }],
                server_codec_id: sc_id as usize,
                server_codec: server_quant.clone(),
                uploads: 0,
                upload_bytes: 0,
                partials: 0,
                broadcast_frames: 0,
                broadcast_bytes: 0,
                skipped_broadcasts: 0,
                catch_up_frames: 0,
                full_syncs: 0,
                ingest_ns: 0,
                send_ns: 0,
                staleness: StalenessHist::default(),
            });
        }

        // upstream reader: broadcasts/shutdown arrive on the same fan-in
        // channel under the sentinel id
        let mut up_reader = up.reader.try_clone().context("cloning upstream stream")?;
        let up_tx = tx.clone();
        let up_handle = std::thread::spawn(move || {
            loop {
                match read_msg_classified(&mut up_reader) {
                    ReadOutcome::Msg(msg) => {
                        // exit on Shutdown (as the flat worker's replica
                        // thread does) so this clone of the upstream
                        // socket closes and the root sees our EOF after
                        // the Bye — otherwise neither side ever closes
                        let stop = matches!(msg, Message::Shutdown);
                        if up_tx.send((UPSTREAM, Ok(Some(msg)))).is_err() || stop {
                            break;
                        }
                    }
                    ReadOutcome::Disconnected(_) => {
                        let _ = up_tx.send((UPSTREAM, Ok(None)));
                        break;
                    }
                    ReadOutcome::BadFrame(e) => {
                        let _ = up_tx.send((UPSTREAM, Err(e)));
                        break;
                    }
                }
            }
        });
        drop(tx);

        // --- main loop -------------------------------------------------
        let mut replica_t = 0u64;
        let mut live = n_workers;
        let mut shutdown_relayed = false;
        while live > 0 {
            let (from, incoming) = rx.recv().map_err(|_| anyhow!("all peers gone"))?;
            let msg = match incoming {
                Ok(Some(m)) => m,
                Ok(None) => {
                    if from == UPSTREAM {
                        if shutdown_relayed {
                            continue; // root closed after shutdown: normal
                        }
                        bail!("upstream leader disconnected mid-run");
                    }
                    live -= 1;
                    continue;
                }
                Err(e) => {
                    if from == UPSTREAM {
                        return Err(e.context("reading from upstream leader"));
                    }
                    if shutdown_relayed {
                        live -= 1;
                        continue;
                    }
                    return Err(e.context(format!(
                        "reading from worker {from} ({})",
                        stats[from as usize].peer
                    )));
                }
            };
            if from == UPSTREAM {
                match msg {
                    Message::Broadcast { t, absolute, payload } => {
                        // one re-base is admitted, like the flat worker's
                        // replica: after a root resume the first relayed
                        // broadcast is the resumed step + 1
                        if t != replica_t + 1 && !(replica_t == 0 && t > 0) {
                            bail!("edge {edge_worker_id}: broadcast gap {replica_t} -> {t}");
                        }
                        replica_t = t;
                        // budgeted runs track the stream's full state
                        // *before* any queue sees the frame, so a
                        // writer's fold always covers what it skipped
                        if let Some(src) = &edge_replica {
                            let mut r = src.lock().unwrap();
                            if r.t == 0 && t > 1 {
                                r.t = t - 1;
                            }
                            let b = Broadcast {
                                t,
                                bytes: payload.len(),
                                msg: QuantizedMsg { payload: payload.clone(), d },
                                absolute,
                                codec: sc_id as usize,
                            };
                            r.apply(&b).context("edge replica: applying relayed broadcast")?;
                        }
                        // relay byte-identically (same deterministic
                        // encoding the root framed), shared across all
                        // downstream writer queues
                        let frame: Arc<[u8]> =
                            frame_bytes(&Message::Broadcast { t, absolute, payload })?.into();
                        for q in &queues {
                            q.push_step(t, frame.clone());
                        }
                    }
                    Message::Sync { t, x } => {
                        // the root folded a backlog for *this edge* into
                        // a full-state resync: every downstream replica
                        // is equally behind, so relay it as a control
                        // frame (never evicted)
                        if t < replica_t {
                            bail!(
                                "edge {edge_worker_id}: stale upstream Sync t={t} at {replica_t}"
                            );
                        }
                        replica_t = t;
                        if let Some(src) = &edge_replica {
                            src.lock()
                                .unwrap()
                                .resync(t, x.clone())
                                .context("edge replica: applying upstream Sync")?;
                        }
                        let frame: Arc<[u8]> = frame_bytes(&Message::Sync { t, x })?.into();
                        for q in &queues {
                            q.push_control(frame.clone());
                        }
                    }
                    Message::Shutdown => {
                        let frame: Arc<[u8]> = frame_bytes(&Message::Shutdown)?.into();
                        for q in &queues {
                            q.push_control(frame.clone());
                        }
                        shutdown_relayed = true;
                    }
                    other => bail!("edge {edge_worker_id}: unexpected upstream {other:?}"),
                }
                continue;
            }
            // downstream traffic
            let wid = from as usize;
            let (t_start, codec_id, payload) = match msg {
                Message::UpdateV2 { t_start, codec_id, payload, .. } => {
                    (t_start, codec_id as usize, payload)
                }
                Message::Bye { worker_id: wid2, uploads } => {
                    tracing_log(&format!("edge: worker {wid2} done ({uploads} uploads)"));
                    continue;
                }
                Message::Update { .. } => {
                    bail!("worker {from}: v1 Update frame — edge leaders are v2-only")
                }
                other => {
                    tracing_log(&format!("edge: unexpected message from {from}: {other:?}"));
                    continue;
                }
            };
            if shutdown_relayed {
                continue; // late update after shutdown: drop
            }
            if codec_id != stats[wid].codec_id {
                bail!(
                    "worker {from} ({}): upload tagged codec id {codec_id}, but this \
                     connection negotiated codec id {} ('{}')",
                    stats[wid].peer,
                    stats[wid].codec_id,
                    stats[wid].codec
                );
            }
            let qmsg = QuantizedMsg { payload, d };
            let wire = qmsg.wire_bytes();
            let staleness = replica_t.saturating_sub(t_start);
            let timer = crate::telemetry::span_start();
            let outcome = edge.ingest_from(&qmsg, staleness, codec_id).with_context(|| {
                format!(
                    "ingesting upload from worker {from} ({}, codec '{}')",
                    stats[wid].peer,
                    edge.client_codec_name(codec_id)
                )
            })?;
            stats[wid].ingest_ns += crate::telemetry::span_ns(timer);
            stats[wid].uploads += 1;
            stats[wid].upload_bytes += wire as u64;
            // edges never rekey their downstream workers, so every
            // upload lands in the single join-time epoch
            stats[wid].epochs[0].uploads += 1;
            stats[wid].epochs[0].upload_bytes += wire as u64;
            stats[wid].staleness.record(staleness);
            match outcome {
                AggOutcome::Buffered => {}
                AggOutcome::Forward(p) => {
                    up.send(&Message::update_partial_from(edge_worker_id, 0, &p))
                        .context("forwarding partial aggregate upstream")?;
                }
                AggOutcome::Stepped(_) => {
                    bail!("internal: edge {edge_worker_id} stepped (edges never step)")
                }
            }
        }

        // goodbye upstream (best effort; root may already be closing),
        // then drain: close the outbound queues, join writers + readers
        let _ = up.send(&Message::Bye { worker_id: edge_worker_id, uploads: edge.forwarded });
        drop(up);
        for q in &queues {
            q.close();
        }
        for (i, h) in writer_handles.into_iter().enumerate() {
            if let Ok((frames, bytes, send_ns, catch_up_frames, full_syncs)) = h.join() {
                stats[i].broadcast_frames = frames;
                stats[i].broadcast_bytes = bytes;
                stats[i].send_ns = send_ns;
                stats[i].catch_up_frames = catch_up_frames;
                stats[i].full_syncs = full_syncs;
            }
            stats[i].skipped_broadcasts = queues[i].skipped();
        }
        for h in reader_handles {
            let _ = h.join();
        }
        let _ = up_handle.join();

        Ok(EdgeReport {
            edge_worker_id,
            d,
            updates: edge.updates,
            update_bytes: edge.update_bytes,
            partials: edge.forwarded,
            partial_bytes: edge.forwarded_bytes,
            pending_at_shutdown: edge.pending(),
            replica_t,
            partial_codec: edge.partial_codec_name(),
            staleness: edge.staleness.clone(),
            worker_stats: stats,
        })
    }
}

fn tracing_log(msg: &str) {
    if std::env::var("QAFEL_NET_LOG").is_ok() {
        eprintln!("{msg}");
    }
}
