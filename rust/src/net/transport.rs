//! Length-prefixed framing over TCP (and any `Read + Write` stream).
//!
//! Frame layout: `[ len : u32 LE ][ body : len bytes ]`, body encoded by
//! [`super::message::Message`]. Max frame size guards against corrupt
//! peers.

use super::message::Message;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;

/// 64 MiB: generously above the largest possible model broadcast.
pub const MAX_FRAME: usize = 64 << 20;

/// Write one message as a frame.
pub fn write_msg<W: Write>(w: &mut W, msg: &Message) -> Result<()> {
    let body = msg.encode();
    if body.len() > MAX_FRAME {
        bail!("frame too large: {} bytes", body.len());
    }
    w.write_all(&(body.len() as u32).to_le_bytes()).context("writing frame length")?;
    w.write_all(&body).context("writing frame body")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one message; `Ok(None)` on clean EOF at a frame boundary.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Option<Message>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e).context("reading frame length"),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        bail!("peer sent oversized frame ({len} bytes)");
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("reading frame body")?;
    Ok(Some(Message::decode(&body)?))
}

/// A connected duplex channel (cloned handles for reader/writer threads).
pub struct Conn {
    pub reader: TcpStream,
    pub writer: TcpStream,
}

impl Conn {
    pub fn from_stream(stream: TcpStream) -> Result<Conn> {
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone().context("cloning tcp stream")?;
        Ok(Conn { reader: stream, writer })
    }

    pub fn connect(addr: &str) -> Result<Conn> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        Conn::from_stream(stream)
    }

    pub fn send(&mut self, msg: &Message) -> Result<()> {
        write_msg(&mut self.writer, msg)
    }

    pub fn recv(&mut self) -> Result<Option<Message>> {
        read_msg(&mut self.reader)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip_over_buffer() {
        let mut buf = Vec::new();
        let msgs = vec![
            Message::Shutdown,
            Message::Broadcast { t: 1, absolute: false, payload: vec![7; 33] },
            Message::Bye { worker_id: 9, uploads: 5 },
        ];
        for m in &msgs {
            write_msg(&mut buf, m).unwrap();
        }
        let mut cur = Cursor::new(buf);
        for m in &msgs {
            assert_eq!(read_msg(&mut cur).unwrap().unwrap(), *m);
        }
        assert!(read_msg(&mut cur).unwrap().is_none()); // clean EOF
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut cur = Cursor::new(buf);
        assert!(read_msg(&mut cur).is_err());
    }

    #[test]
    fn tcp_roundtrip() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut conn = Conn::from_stream(stream).unwrap();
            let m = conn.recv().unwrap().unwrap();
            conn.send(&m).unwrap(); // echo
        });
        let mut conn = Conn::connect(&addr.to_string()).unwrap();
        let msg = Message::Update {
            worker_id: 1,
            t_start: 2,
            trip: 3,
            train_loss: 0.5,
            payload: vec![1, 2, 3],
        };
        conn.send(&msg).unwrap();
        assert_eq!(conn.recv().unwrap().unwrap(), msg);
        server.join().unwrap();
    }
}
