//! Length-prefixed framing over TCP (and any `Read + Write` stream).
//!
//! Frame layout: `[ len : u32 LE ][ body : len bytes ]`, body encoded by
//! [`super::message::Message`]. Max frame size guards against corrupt
//! peers.

use super::message::Message;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;

/// 64 MiB: generously above the largest possible model broadcast.
pub const MAX_FRAME: usize = 64 << 20;

/// Encode one message as a complete frame (length prefix + body) ready
/// for `write_all`. The leader's broadcast fan-out encodes each frame
/// exactly once with this and shares the bytes across all per-worker
/// writer threads via `Arc<[u8]>`.
pub fn frame_bytes(msg: &Message) -> Result<Vec<u8>> {
    let body = msg.encode();
    if body.len() > MAX_FRAME {
        bail!("frame too large: {} bytes", body.len());
    }
    let mut frame = Vec::with_capacity(4 + body.len());
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    frame.extend_from_slice(&body);
    Ok(frame)
}

/// Write one message as a frame.
pub fn write_msg<W: Write>(w: &mut W, msg: &Message) -> Result<()> {
    let frame = frame_bytes(msg)?;
    w.write_all(&frame).context("writing frame")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Outcome of [`read_msg_classified`]: separates peer death (the
/// connection is simply gone — tolerable) from protocol violations
/// (the peer is alive but sent garbage — worth failing loudly on).
#[derive(Debug)]
pub enum ReadOutcome {
    /// One well-formed message.
    Msg(Message),
    /// Clean EOF at a frame boundary (`None`), or a transport-level
    /// I/O failure — reset, abort, EOF mid-frame (`Some(e)`).
    Disconnected(Option<std::io::Error>),
    /// The peer sent an oversized length prefix or a frame body that
    /// fails to decode.
    BadFrame(anyhow::Error),
}

/// Read one message, classifying failures. The leader's per-worker
/// reader threads use this to keep the old tolerance for workers that
/// die mid-run (a disconnect, as before) while surfacing corrupt
/// frames as hard errors with connection context.
pub fn read_msg_classified<R: Read>(r: &mut R) -> ReadOutcome {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return ReadOutcome::Disconnected(None);
        }
        Err(e) => return ReadOutcome::Disconnected(Some(e)),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return ReadOutcome::BadFrame(anyhow::anyhow!("peer sent oversized frame ({len} bytes)"));
    }
    let mut body = vec![0u8; len];
    if let Err(e) = r.read_exact(&mut body) {
        return ReadOutcome::Disconnected(Some(e));
    }
    match Message::decode(&body) {
        Ok(msg) => ReadOutcome::Msg(msg),
        Err(e) => ReadOutcome::BadFrame(e),
    }
}

/// Read one message; `Ok(None)` on clean EOF at a frame boundary.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Option<Message>> {
    match read_msg_classified(r) {
        ReadOutcome::Msg(msg) => Ok(Some(msg)),
        ReadOutcome::Disconnected(None) => Ok(None),
        ReadOutcome::Disconnected(Some(e)) => Err(e).context("reading frame"),
        ReadOutcome::BadFrame(e) => Err(e),
    }
}

/// A connected duplex channel (cloned handles for reader/writer threads).
pub struct Conn {
    pub reader: TcpStream,
    pub writer: TcpStream,
}

impl Conn {
    pub fn from_stream(stream: TcpStream) -> Result<Conn> {
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone().context("cloning tcp stream")?;
        Ok(Conn { reader: stream, writer })
    }

    pub fn connect(addr: &str) -> Result<Conn> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        Conn::from_stream(stream)
    }

    pub fn send(&mut self, msg: &Message) -> Result<()> {
        write_msg(&mut self.writer, msg)
    }

    pub fn recv(&mut self) -> Result<Option<Message>> {
        read_msg(&mut self.reader)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip_over_buffer() {
        let mut buf = Vec::new();
        let msgs = vec![
            Message::Shutdown,
            Message::Broadcast { t: 1, absolute: false, payload: vec![7; 33] },
            Message::Bye { worker_id: 9, uploads: 5 },
        ];
        for m in &msgs {
            write_msg(&mut buf, m).unwrap();
        }
        let mut cur = Cursor::new(buf);
        for m in &msgs {
            assert_eq!(read_msg(&mut cur).unwrap().unwrap(), *m);
        }
        assert!(read_msg(&mut cur).unwrap().is_none()); // clean EOF
    }

    #[test]
    fn frame_bytes_matches_write_msg() {
        let msgs = vec![
            Message::Shutdown,
            Message::Hello {
                version: 2,
                tier: Some("slow".into()),
                quant_client: None,
                bandwidth_hint: None,
            },
            Message::Broadcast { t: 3, absolute: false, payload: vec![1, 2, 3] },
        ];
        for m in &msgs {
            let frame = frame_bytes(m).unwrap();
            let mut streamed = Vec::new();
            write_msg(&mut streamed, m).unwrap();
            assert_eq!(frame, streamed);
            // and it reads back as one message
            let mut cur = Cursor::new(frame);
            assert_eq!(read_msg(&mut cur).unwrap().unwrap(), *m);
        }
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut cur = Cursor::new(buf);
        assert!(read_msg(&mut cur).is_err());
    }

    #[test]
    fn read_classification_separates_death_from_garbage() {
        // clean EOF at a frame boundary: disconnected, no error
        let mut cur = Cursor::new(Vec::<u8>::new());
        assert!(matches!(
            read_msg_classified(&mut cur),
            ReadOutcome::Disconnected(None)
        ));
        // EOF mid-frame (peer died while sending): transport-level
        let mut partial = Vec::new();
        write_msg(&mut partial, &Message::Shutdown).unwrap();
        partial.pop();
        let mut cur = Cursor::new(partial);
        assert!(matches!(
            read_msg_classified(&mut cur),
            ReadOutcome::Disconnected(Some(_))
        ));
        // oversized length prefix: protocol violation
        let mut cur = Cursor::new((u32::MAX).to_le_bytes().to_vec());
        assert!(matches!(read_msg_classified(&mut cur), ReadOutcome::BadFrame(_)));
        // well-framed garbage body (unknown tag): protocol violation
        let mut buf = Vec::new();
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.push(99);
        let mut cur = Cursor::new(buf);
        match read_msg_classified(&mut cur) {
            ReadOutcome::BadFrame(e) => {
                assert!(e.to_string().contains("unknown message tag"), "{e}");
            }
            other => panic!("expected BadFrame, got {other:?}"),
        }
    }

    #[test]
    fn tcp_roundtrip() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut conn = Conn::from_stream(stream).unwrap();
            let m = conn.recv().unwrap().unwrap();
            conn.send(&m).unwrap(); // echo
        });
        let mut conn = Conn::connect(&addr.to_string()).unwrap();
        let msg = Message::Update {
            worker_id: 1,
            t_start: 2,
            trip: 3,
            train_loss: 0.5,
            payload: vec![1, 2, 3],
        };
        conn.send(&msg).unwrap();
        assert_eq!(conn.recv().unwrap().unwrap(), msg);
        server.join().unwrap();
    }
}
