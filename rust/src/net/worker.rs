//! A worker process: Algorithm 2 (train + quantized upload) with
//! Algorithm 3 (hidden-state replica) as a real background reader thread.
//!
//! Speaks wire protocol v2 by default: it opens with a `Hello` carrying
//! its protocol version and requested upload codec (an explicit
//! `quant_client` spec or a device-tier name), and expects a `JoinV2`
//! assigning the resolved codec and its registry id; every upload is
//! then an `UpdateV2` tagged with that id. If the leader answers with a
//! legacy `Join` instead, the worker falls back to v1 — default codec,
//! untagged `Update` frames. Note the fallback covers leaders that
//! *deliberately* speak v1 after a Hello (minimal implementations,
//! test stubs); a genuine pre-v2 leader cannot decode the Hello frame
//! at all and drops the connection, so mixed-version deployments must
//! upgrade the leader first (the supported direction is new leader +
//! old workers, via [`Worker::force_v1`]-style silent v1 joins, which
//! the leader serves bit-identically).

use super::message::{Message, PROTOCOL_VERSION};
use super::transport::Conn;
use crate::quant::parse_spec;
use crate::runtime::Backend;
use crate::util::prng::Prng;
use anyhow::{bail, Result};
use std::sync::mpsc;

/// Worker run summary.
#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub worker_id: u32,
    pub uploads: u64,
    /// Final replica step (how far the hidden state advanced).
    pub replica_t: u64,
    /// Protocol version the connection actually ran at (1 or 2).
    pub protocol: u8,
    /// Resolved upload-codec spec the leader assigned.
    pub codec: String,
    /// Registry id of that codec on the leader (0 = default). After a
    /// mid-run `Rekey` this (and `codec`) reflects the *final* codec.
    pub codec_id: u32,
    /// Mid-run `Rekey` switches this worker applied (0 when the leader
    /// runs without `net.adaptive`).
    pub rekeys: u64,
    /// Resolved downlink-codec spec the leader assigned (the tier's
    /// `quant_server` preset, else the default `quant.server`).
    pub server_codec: String,
    /// Downlink-family id of that codec on the leader (0 = default).
    pub server_codec_id: u32,
    /// Full-state `Sync` frames applied: budgeted fan-out catch-ups
    /// that could not be expressed as replayed increments (0 unless
    /// the leader runs with `net.broadcast_budget_bytes` and this
    /// worker fell far behind).
    pub syncs: u64,
    /// Wall time in local training rounds (`client_round`). All `_ns`
    /// counters are captured only while telemetry spans are on
    /// ([`crate::telemetry::set_enabled`]); zero otherwise.
    pub train_ns: u64,
    /// Wall time quantizing upload deltas (Q_c encode).
    pub encode_ns: u64,
    /// Wall time in socket writes for uploads.
    pub send_ns: u64,
    /// Wall time applying received broadcasts to the replica (Q_s
    /// decode + hidden-state advance, Algorithm 3).
    pub decode_ns: u64,
    /// Adversary spec this worker ran with (`""` = honest).
    pub adversary: String,
}

/// A worker: owns a compute backend and a hidden-state replica.
pub struct Worker<B: Backend> {
    backend: B,
    /// Sleep this long between rounds to emulate slow clients (tests 0).
    pub round_delay: std::time::Duration,
    /// Shard-parallel broadcast decode (mirrors the server's
    /// `cfg.fl.shards`; worth > 1 only for multi-MB models).
    pub shards: usize,
    /// Device-tier name sent in the v2 Hello; the leader resolves it to
    /// `scenario.tiers.<name>.quant_client` (`net.tier` / `--tier`).
    pub tier: Option<String>,
    /// Explicit upload-codec spec sent in the v2 Hello; wins over
    /// `tier` on the leader (`net.quant_client` / `--quant-client`).
    pub quant_client: Option<String>,
    /// Uplink bandwidth hint in Mbit/s sent in the v2 Hello
    /// (`--bandwidth-mbps`); the leader's adaptive controller scores
    /// this worker by it. `None` = no hint (byte-identical Hello to
    /// the pre-hint layout).
    pub bandwidth_hint: Option<f32>,
    /// Speak the legacy v1 protocol (no Hello, untagged uploads).
    pub force_v1: bool,
    /// Adversarial upload behavior (`qafel worker --adversary`):
    /// `sign_flip` | `scale:<c>` | `stale_replay`, applied to every
    /// delta after local training and before quantization — the same
    /// transform point as a hostile simulator tier
    /// (`crate::scenario::Adversary`). `None` is an honest worker.
    pub adversary: Option<String>,
}

impl<B: Backend> Worker<B> {
    pub fn new(backend: B) -> Worker<B> {
        Worker {
            backend,
            round_delay: std::time::Duration::ZERO,
            shards: 1,
            tier: None,
            quant_client: None,
            bandwidth_hint: None,
            force_v1: false,
            adversary: None,
        }
    }

    /// Connect to the leader at `addr` and train until Shutdown.
    pub fn run(&self, addr: &str) -> Result<WorkerReport> {
        // parse the adversary spec before connecting: a bad spec fails
        // fast instead of joining and then dying mid-run
        let adversary = match &self.adversary {
            Some(spec) => Some(crate::scenario::Adversary::parse(spec)?),
            None => None,
        };
        let mut replay_cache: Option<Vec<f32>> = None;
        let mut conn = Conn::connect(addr)?;
        // --- join -----------------------------------------------------------
        // v2 opens with Hello; the legacy flow waits silently for Join.
        if !self.force_v1 {
            conn.send(&Message::Hello {
                version: PROTOCOL_VERSION,
                tier: self.tier.clone(),
                quant_client: self.quant_client.clone(),
                bandwidth_hint: self.bandwidth_hint,
            })?;
        }
        let (protocol, worker_id, d, x0, client_quant, server_quant, client_lr, mut codec_id, sc_id) =
            match conn.recv()? {
                Some(Message::JoinV2 {
                    version,
                    worker_id,
                    d,
                    x0,
                    client_quant,
                    server_quant,
                    client_lr,
                    codec_id,
                    server_codec_id,
                }) => {
                    if self.force_v1 {
                        bail!("worker: leader sent JoinV2 to a v1 worker");
                    }
                    (
                        version.min(PROTOCOL_VERSION),
                        worker_id,
                        d as usize,
                        x0,
                        client_quant,
                        server_quant,
                        client_lr,
                        codec_id,
                        server_codec_id,
                    )
                }
                // a leader that answers a Hello with the legacy Join is
                // deliberately speaking v1: fall back (default codec,
                // id 0). A genuine pre-v2 leader never gets here — it
                // fails to decode the Hello tag and drops us instead.
                Some(Message::Join { worker_id, d, x0, client_quant, server_quant, client_lr }) => {
                    (1u8, worker_id, d as usize, x0, client_quant, server_quant, client_lr, 0, 0)
                }
                other => bail!("expected Join/JoinV2, got {other:?}"),
            };
        if d != self.backend.d() {
            bail!("model dim mismatch: leader d={d}, backend d={}", self.backend.d());
        }
        let mut quant_c = parse_spec(&client_quant)?;
        let mut rng = Prng::new(0xC11E27 ^ worker_id as u64).stream("worker-quant");
        // adversary draws (scale:<c> garbage) live on their own stream,
        // so an honest worker's quantizer noise is untouched
        let mut adv_rng = Prng::new(0xC11E27 ^ worker_id as u64).stream("worker-adversary");
        // Algorithm 3's replica, decoding with the downlink codec this
        // connection's tier negotiated (JoinV2.server_quant); the decode
        // pool is persistent, reused for every broadcast this run
        let pool = crate::util::pool::ShardPool::new(self.shards.max(1));
        let mut replica =
            crate::coordinator::client::HiddenReplica::with_spec(&server_quant, x0, pool)?;

        // --- Algorithm 3: background replica thread -------------------------
        // The reader thread receives broadcasts and forwards them; the
        // training loop applies them in order between rounds (the replica
        // is only *read* at round start, so this is equivalent to applying
        // them the moment they arrive). The channel is *bounded*: a worker
        // whose training rounds can't keep up with the broadcast stream
        // stops reading its socket, TCP backpressure fills the leader's
        // budgeted writer queue, and the leader folds the backlog into a
        // catch-up at the source instead of buffering it here unboundedly.
        let (tx, rx) = mpsc::sync_channel::<Message>(256);
        let mut reader = conn.reader.try_clone()?;
        let bg = std::thread::spawn(move || {
            while let Ok(Some(msg)) = super::transport::read_msg(&mut reader) {
                let stop = matches!(msg, Message::Shutdown);
                if tx.send(msg).is_err() || stop {
                    break;
                }
            }
        });

        let mut uploads = 0u64;
        let mut syncs = 0u64;
        let mut rekeys = 0u64;
        let mut trip = 0u64;
        let mut train_ns = 0u64;
        let mut encode_ns = 0u64;
        let mut send_ns = 0u64;
        let mut decode_ns = 0u64;
        'train: loop {
            // drain all pending broadcasts (Algorithm 3 lines 3-4)
            loop {
                match rx.try_recv() {
                    Ok(Message::Broadcast { t, absolute, payload }) => {
                        // the replica admits one re-base: the leader of
                        // a resumed run handed us its checkpointed hidden
                        // state as x^0, and the first broadcast we see is
                        // the resumed step + 1 (writer queues exist before
                        // the coordination loop starts, so nothing between
                        // join and that first frame can be missed)
                        if replica.t == 0 && t > 1 {
                            replica.t = t - 1;
                        }
                        let b = crate::coordinator::Broadcast {
                            t,
                            bytes: payload.len(),
                            msg: crate::quant::QuantizedMsg { payload, d },
                            absolute,
                            codec: sc_id as usize,
                        };
                        let timer = crate::telemetry::span_start();
                        replica
                            .apply(&b)
                            .map_err(|e| e.context(format!("worker {worker_id}")))?;
                        decode_ns += crate::telemetry::span_ns(timer);
                    }
                    Ok(Message::Sync { t, x }) => {
                        // budgeted fan-out: the leader folded a skipped
                        // backlog into one full-state resync (B.1)
                        let timer = crate::telemetry::span_start();
                        replica
                            .resync(t, x)
                            .map_err(|e| e.context(format!("worker {worker_id}")))?;
                        decode_ns += crate::telemetry::span_ns(timer);
                        syncs += 1;
                    }
                    Ok(Message::Rekey { worker_id: wid2, codec_id: new_id, spec, t: _ }) => {
                        // mid-run codec switch from the adaptive
                        // controller: applies from the *next* round —
                        // the upload already in flight keeps its old
                        // tag and the leader's transition window
                        // accepts it
                        if protocol < 2 {
                            bail!("worker {worker_id}: Rekey on a v1 connection");
                        }
                        if wid2 != worker_id {
                            bail!(
                                "worker {worker_id}: Rekey addressed to worker {wid2}"
                            );
                        }
                        quant_c = parse_spec(&spec).map_err(|e| {
                            e.context(format!("worker {worker_id}: bad Rekey spec '{spec}'"))
                        })?;
                        codec_id = new_id;
                        rekeys += 1;
                    }
                    Ok(Message::Shutdown) => break 'train,
                    Ok(other) => bail!("worker {worker_id}: unexpected {other:?}"),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => break 'train,
                }
            }

            // Algorithm 2: train from the replica snapshot
            let t_start = replica.t;
            let user = worker_id as usize;
            let timer = crate::telemetry::span_start();
            let mut out = self.backend.client_round(replica.state(), user, trip, client_lr)?;
            train_ns += crate::telemetry::span_ns(timer);
            if let Some(a) = &adversary {
                a.apply(&mut out.delta, &mut replay_cache, &mut adv_rng);
            }
            let timer = crate::telemetry::span_start();
            let qmsg = quant_c.quantize(&out.delta, &mut rng);
            encode_ns += crate::telemetry::span_ns(timer);
            let upload = if protocol >= 2 {
                Message::update_v2_from(worker_id, t_start, trip, out.loss, codec_id, &qmsg)
            } else {
                Message::update_from(worker_id, t_start, trip, out.loss, &qmsg)
            };
            let timer = crate::telemetry::span_start();
            conn.send(&upload)?;
            send_ns += crate::telemetry::span_ns(timer);
            uploads += 1;
            trip += 1;
            if !self.round_delay.is_zero() {
                std::thread::sleep(self.round_delay);
            }
        }

        // goodbye (best effort; leader may already be closing)
        let _ = conn.send(&Message::Bye { worker_id, uploads });
        let _ = bg.join();
        Ok(WorkerReport {
            worker_id,
            uploads,
            replica_t: replica.t,
            protocol,
            codec: quant_c.name(),
            codec_id,
            rekeys,
            server_codec: server_quant,
            server_codec_id: sc_id,
            syncs,
            train_ns,
            encode_ns,
            send_ns,
            decode_ns,
            adversary: self.adversary.clone().unwrap_or_default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, Config};
    use crate::net::leader::Leader;
    use crate::runtime::QuadraticBackend;

    fn net_cfg() -> Config {
        let mut c = Config::default();
        c.fl.algorithm = Algorithm::Qafel;
        c.quant.client = "qsgd:8".into();
        c.quant.server = "qsgd:8".into();
        c.fl.buffer_size = 3;
        c.fl.client_lr = 0.05;
        c.fl.server_lr = 1.0;
        c.fl.server_momentum = 0.0;
        // workers in a tight loop can produce arbitrarily stale updates
        // (Assumption 3.4 bounds staleness in the analysis); scale them
        // down as the paper's Fig. 3 runs do
        c.fl.staleness_scaling = true;
        c.fl.clip_norm = 0.0;
        c.stop.max_server_steps = 40;
        c.stop.max_uploads = 100_000;
        c
    }

    #[test]
    fn leader_and_workers_over_tcp() {
        let cfg = net_cfg();
        let d = 16;
        let mk_backend =
            || QuadraticBackend::new(d, 8, 1.0, 0.3, 0.2, 0.02, 1, 21);
        let x0 = mk_backend().init_params(0).unwrap();
        let g0 = mk_backend().grad_norm_sq(&x0);

        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let leader_cfg = cfg.clone();
        let leader_x0 = x0.clone();
        let leader = std::thread::spawn(move || {
            Leader::new(leader_cfg, leader_x0, 7).run_on(listener, 4).unwrap()
        });

        let mut workers = Vec::new();
        for _ in 0..4 {
            let addr = addr.clone();
            workers.push(std::thread::spawn(move || {
                let mut w = Worker::new(QuadraticBackend::new(d, 8, 1.0, 0.3, 0.2, 0.02, 1, 21));
                // give broadcasts time to propagate between rounds so the
                // run resembles production pacing rather than a hot spin
                w.round_delay = std::time::Duration::from_millis(1);
                w.run(&addr).unwrap()
            }));
        }

        let report = leader.join().unwrap();
        let mut total_uploads = 0;
        let mut max_replica_t = 0;
        for w in workers {
            let r = w.join().unwrap();
            total_uploads += r.uploads;
            max_replica_t = max_replica_t.max(r.replica_t);
            // plain workers negotiate v2 and land on the default codec
            // in both directions; no budget means no full-state syncs
            assert_eq!(r.protocol, 2);
            assert_eq!(r.codec_id, 0);
            assert_eq!(r.codec, "qsgd:8");
            assert_eq!(r.server_codec_id, 0);
            assert_eq!(r.server_codec, "qsgd:8");
            assert_eq!(r.syncs, 0);
        }

        assert_eq!(report.server_steps, 40);
        assert_eq!(report.comm.broadcasts, 40);
        // every server step consumed K=3 uploads; workers may have a few
        // in-flight extras that were dropped after shutdown
        assert!(report.comm.uploads >= 120, "uploads {}", report.comm.uploads);
        assert!(total_uploads >= report.comm.uploads);
        assert!(max_replica_t > 30, "replicas stalled at {max_replica_t}");
        // per-worker accounting sums to the server totals
        assert_eq!(report.worker_stats.len(), 4);
        let per_worker_uploads: u64 = report.worker_stats.iter().map(|w| w.uploads).sum();
        let per_worker_bytes: u64 = report.worker_stats.iter().map(|w| w.upload_bytes).sum();
        assert_eq!(per_worker_uploads, report.comm.uploads);
        assert_eq!(per_worker_bytes, report.comm.upload_bytes);
        for ws in &report.worker_stats {
            assert_eq!(ws.protocol, 2);
            assert_eq!(ws.codec_id, 0);
            assert_eq!(ws.server_codec_id, 0);
            assert!(ws.uploads > 0, "worker {} never uploaded", ws.worker_id);
            // writer threads delivered every broadcast + the shutdown
            // frame; the default budget (0) never skips or folds
            assert_eq!(ws.broadcast_frames, 41, "worker {}", ws.worker_id);
            assert_eq!(ws.skipped_broadcasts, 0);
            assert_eq!(ws.catch_up_frames, 0);
            assert_eq!(ws.full_syncs, 0);
        }
        // training over TCP actually descends
        let g1 = mk_backend().grad_norm_sq(&report.model);
        assert!(g1 < g0 * 0.8, "{g0} -> {g1}");
    }

    #[test]
    fn worker_rejects_dim_mismatch() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let srv = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut conn = Conn::from_stream(stream).unwrap();
            conn.send(&Message::Join {
                worker_id: 0,
                d: 99,
                x0: vec![0.0; 99],
                client_quant: "none".into(),
                server_quant: "none".into(),
                client_lr: 0.1,
            })
            .unwrap();
            // keep the socket open until the worker errors out
            let _ = conn.recv();
        });
        let w = Worker::new(QuadraticBackend::new(4, 2, 1.0, 0.5, 0.1, 0.0, 1, 1));
        assert!(w.run(&addr).is_err());
        srv.join().unwrap();
    }
}
