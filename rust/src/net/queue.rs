//! Bounded per-connection broadcast queues for the fan-out writers.
//!
//! The v1/v2 leaders and edge relays used to hand each writer thread an
//! unbounded `mpsc` channel: one stalled TCP peer (a phone on a dead
//! radio, a throttled edge) made the leader buffer every broadcast frame
//! it would never drain — memory grew linearly with server steps. A
//! [`FrameQueue`] caps the bytes queued per connection
//! (`net.broadcast_budget_bytes`) and, when over budget, evicts the
//! *oldest* step frames first: the newest broadcast always survives, the
//! skipped ones are folded into the writer's next send via the server's
//! [`crate::coordinator::UpdateLog`] (incremental catch-up) or a full
//! [`crate::net::message::Message::Sync`] when the log has evicted the
//! increments (Appendix B.1's bounded catch-up rule).
//!
//! Control frames (Join, Shutdown, relayed Syncs) are never evicted and
//! never count against the budget — dropping them would wedge the
//! protocol, and they are O(1) per connection.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

/// One frame queued for a writer thread.
#[derive(Clone)]
pub enum QueuedFrame {
    /// A broadcast frame for server step `t`. Evictable under budget
    /// pressure: a newer step supersedes it and the gap is folded into a
    /// catch-up by the writer.
    Step { t: u64, frame: Arc<[u8]> },
    /// A protocol frame (Shutdown, relayed Sync). Never evicted, never
    /// counted against the budget.
    Control(Arc<[u8]>),
}

struct Inner {
    items: VecDeque<QueuedFrame>,
    /// Bytes held by `Step` items only.
    step_bytes: u64,
    /// 0 = unlimited (the pre-budget behavior, byte-for-byte).
    budget: u64,
    skipped: u64,
    closed: bool,
}

/// A bounded MPSC frame queue: the main loop pushes, one writer pops.
pub struct FrameQueue {
    inner: Mutex<Inner>,
    cond: Condvar,
}

impl FrameQueue {
    /// `budget` bounds the bytes of queued `Step` frames; 0 = unlimited.
    pub fn new(budget: u64) -> Arc<FrameQueue> {
        Arc::new(FrameQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::new(),
                step_bytes: 0,
                budget,
                skipped: 0,
                closed: false,
            }),
            cond: Condvar::new(),
        })
    }

    /// Enqueue a broadcast frame for step `t`, evicting oldest step
    /// frames while over budget. The newest frame is always enqueued,
    /// even when it alone exceeds the budget — the writer needs *some*
    /// frame to anchor its catch-up fold.
    pub fn push_step(&self, t: u64, frame: Arc<[u8]>) {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return;
        }
        g.step_bytes += frame.len() as u64;
        g.items.push_back(QueuedFrame::Step { t, frame });
        if g.budget > 0 {
            while g.step_bytes > g.budget && g.items.len() > 1 {
                // Evict the oldest Step item, keeping Control frames and
                // always keeping the just-pushed newest step.
                let Some(pos) = g.items.iter().position(|i| matches!(i, QueuedFrame::Step { .. }))
                else {
                    break;
                };
                if pos == g.items.len() - 1 {
                    break; // only the newest step remains
                }
                if let Some(QueuedFrame::Step { frame, .. }) = g.items.remove(pos) {
                    g.step_bytes -= frame.len() as u64;
                    g.skipped += 1;
                }
            }
        }
        drop(g);
        self.cond.notify_one();
    }

    /// Enqueue a protocol frame. Exempt from the budget and eviction.
    pub fn push_control(&self, frame: Arc<[u8]>) {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return;
        }
        g.items.push_back(QueuedFrame::Control(frame));
        drop(g);
        self.cond.notify_one();
    }

    /// Pop the next frame, blocking while the queue is open and empty.
    /// After [`FrameQueue::close`], drains remaining items then returns
    /// `None`.
    pub fn pop(&self) -> Option<QueuedFrame> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                if let QueuedFrame::Step { frame, .. } = &item {
                    g.step_bytes -= frame.len() as u64;
                }
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.cond.wait(g).unwrap();
        }
    }

    /// Close the queue: pushes become no-ops, `pop` drains then ends.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cond.notify_all();
    }

    /// Step frames evicted under budget pressure so far.
    pub fn skipped(&self) -> u64 {
        self.inner.lock().unwrap().skipped
    }

    /// Bytes currently held by queued step frames.
    pub fn queued_bytes(&self) -> u64 {
        self.inner.lock().unwrap().step_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(n: usize) -> Arc<[u8]> {
        Arc::from(vec![0u8; n].into_boxed_slice())
    }

    fn pop_step_t(q: &FrameQueue) -> u64 {
        match q.pop() {
            Some(QueuedFrame::Step { t, .. }) => t,
            other => panic!("expected a step frame, got none/control: {}", other.is_some()),
        }
    }

    #[test]
    fn unlimited_budget_never_evicts() {
        let q = FrameQueue::new(0);
        for t in 1..=100u64 {
            q.push_step(t, frame(1000));
        }
        assert_eq!(q.skipped(), 0);
        assert_eq!(q.queued_bytes(), 100_000);
        for t in 1..=100u64 {
            assert_eq!(pop_step_t(&q), t);
        }
    }

    #[test]
    fn over_budget_evicts_oldest_keeps_newest() {
        let q = FrameQueue::new(2500); // fits 2 × 1000-byte frames + slack
        for t in 1..=10u64 {
            q.push_step(t, frame(1000));
        }
        assert_eq!(q.skipped(), 8);
        assert!(q.queued_bytes() <= 2500);
        assert_eq!(pop_step_t(&q), 9);
        assert_eq!(pop_step_t(&q), 10);
    }

    #[test]
    fn oversized_newest_frame_still_enqueued() {
        let q = FrameQueue::new(10);
        q.push_step(1, frame(1000));
        assert_eq!(q.skipped(), 0, "a lone over-budget frame must survive");
        assert_eq!(pop_step_t(&q), 1);
    }

    #[test]
    fn control_frames_exempt_from_budget_and_eviction() {
        let q = FrameQueue::new(1500);
        q.push_control(frame(10_000));
        q.push_step(1, frame(1000));
        q.push_step(2, frame(1000));
        q.push_step(3, frame(1000));
        // steps 1 and 2 evicted; the huge control frame untouched
        assert_eq!(q.skipped(), 2);
        assert!(matches!(q.pop(), Some(QueuedFrame::Control(_))));
        assert_eq!(pop_step_t(&q), 3);
    }

    #[test]
    fn close_drains_then_ends() {
        let q = FrameQueue::new(0);
        q.push_step(1, frame(8));
        q.close();
        q.push_step(2, frame(8)); // dropped: closed
        assert_eq!(pop_step_t(&q), 1);
        assert!(q.pop().is_none());
    }

    #[test]
    fn pop_blocks_until_push_or_close() {
        let q = FrameQueue::new(0);
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let mut seen = Vec::new();
            while let Some(QueuedFrame::Step { t, .. }) = q2.pop() {
                seen.push(t);
            }
            seen
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.push_step(1, frame(4));
        q.push_step(2, frame(4));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), vec![1, 2]);
    }
}
