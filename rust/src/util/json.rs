//! Minimal JSON parser + writer (no serde available offline).
//!
//! Used to read `artifacts/manifest.json` produced by the AOT pipeline and
//! to write experiment reports. Supports the full JSON grammar except for
//! `\u` surrogate pairs beyond the BMP (sufficient for our machine-written
//! documents); numbers are f64 (exact for the integer ranges we use).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style multi-level access.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn arr(vs: Vec<Json>) -> Json {
        Json::Arr(vs)
    }

    /// Pretty-print with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like_document() {
        let text = r#"{
          "format": "qafel-artifacts-v1",
          "model": {"d": 29474, "layers": [{"name": "conv0/w", "shape": [3,3,3,32], "offset": 0, "size": 864}]},
          "train": {"batch": 32, "local_steps": 1},
          "flag": true, "opt": null, "lr": 4.7e-06
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.at(&["model", "d"]).unwrap().as_usize(), Some(29474));
        assert_eq!(
            v.at(&["model", "layers"]).unwrap().as_arr().unwrap()[0]
                .get("name").unwrap().as_str(),
            Some("conv0/w")
        );
        assert_eq!(v.get("flag").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("opt"), Some(&Json::Null));
        assert!((v.get("lr").unwrap().as_f64().unwrap() - 4.7e-6).abs() < 1e-12);
    }

    #[test]
    fn roundtrip_via_writer() {
        let v = Json::obj(vec![
            ("a", Json::arr(vec![Json::num(1.0), Json::num(2.5), Json::Null])),
            ("s", Json::str("hi \"there\"\n")),
            ("b", Json::Bool(false)),
            ("nested", Json::obj(vec![("x", Json::num(-3.0))])),
        ]);
        let text = v.pretty();
        let v2 = Json::parse(&text).unwrap();
        assert_eq!(v, v2);
        let compact = v.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::num(29474.0).to_string(), "29474");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }
}
