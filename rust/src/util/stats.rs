//! Descriptive statistics for experiment reporting (mean ± std over
//! seeds, as the paper reports) and benchmark summaries.

/// Streaming mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation of a slice.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile with linear interpolation; `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Ordinary least squares slope of y against x — used by the convergence
/// experiment to estimate empirical decay orders (log-log slopes).
pub fn ols_slope(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let _n = x.len() as f64;
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..x.len() {
        num += (x[i] - mx) * (y[i] - my);
        den += (x[i] - mx) * (x[i] - mx);
    }
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(o.min(), 1.0);
        assert_eq!(o.max(), 10.0);
        assert_eq!(o.count(), 5);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn slope_of_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v + 2.0).collect();
        assert!((ols_slope(&x, &y) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_slices() {
        assert!(mean(&[]).is_nan());
        assert_eq!(std(&[1.0]), 0.0);
        assert!(percentile(&[], 50.0).is_nan());
    }
}
