//! Persistent shard worker pool for the sharded aggregation pipeline.
//!
//! The first sharded server (DESIGN_SHARDING.md) ran every parallel
//! stage on `std::thread::scope`, paying a thread spawn + join per stage
//! (~10–50 µs) — several times per server step, which dominates the
//! d < 1M regime. [`ShardPool`] amortizes that: `S - 1` long-lived
//! workers are spawned once (the caller is the S-th lane), each step
//! hands tasks over a shared queue, and the pool joins its workers on
//! drop.
//!
//! Safety model: [`ShardPool::run`] accepts non-`'static` closures (the
//! per-shard tasks borrow disjoint `&mut` sub-slices of the caller's
//! buffers, exactly like scoped threads). The lifetime is erased with a
//! `transmute`, which is sound because `run` never returns — not even on
//! the panic path — before every submitted task has completed, so the
//! borrows outlive the tasks.
//!
//! Panic policy: a panicking task never takes a worker down or wedges
//! the queue. Workers catch the payload, the remaining tasks of the
//! batch still run, and `run` re-raises the first payload on the caller
//! once the batch has drained — a panic propagates instead of hanging,
//! and the pool stays usable afterwards.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Worker threads ever spawned by any pool in this process. Steady-state
/// regression guard: server steps must not move this counter
/// (`rust/tests/pool_lifecycle.rs`).
static THREADS_SPAWNED: AtomicUsize = AtomicUsize::new(0);
/// Worker threads currently alive (spawned minus exited-and-joined).
static LIVE_WORKERS: AtomicUsize = AtomicUsize::new(0);

/// Total pool worker threads ever spawned in this process.
pub fn threads_spawned_total() -> usize {
    THREADS_SPAWNED.load(Ordering::SeqCst)
}

/// Pool worker threads currently alive in this process.
pub fn live_workers_total() -> usize {
    LIVE_WORKERS.load(Ordering::SeqCst)
}

/// Non-empty task batches executed through [`ShardPool::run`] across
/// every pool in this process (utilization telemetry: together with
/// [`tasks_run_total`], `tasks / batches` is the average shard fan-out
/// actually submitted — vs the configured shard count).
static BATCHES_RUN: AtomicUsize = AtomicUsize::new(0);
/// Individual shard tasks executed across every pool in this process.
static TASKS_RUN: AtomicUsize = AtomicUsize::new(0);

/// Task batches run through any pool in this process.
pub fn batches_run_total() -> usize {
    BATCHES_RUN.load(Ordering::SeqCst)
}

/// Shard tasks run through any pool in this process.
pub fn tasks_run_total() -> usize {
    TASKS_RUN.load(Ordering::SeqCst)
}

/// A borrowed task, valid for `'a` (the duration of the `run` call).
pub type Task<'a> = Box<dyn FnOnce() + Send + 'a>;
type StaticTask = Box<dyn FnOnce() + Send + 'static>;

/// Completion state of one `run` batch.
struct RunState {
    remaining: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl RunState {
    fn new(n: usize) -> RunState {
        RunState { remaining: Mutex::new(n), done: Condvar::new(), panic: Mutex::new(None) }
    }

    /// Record one finished task (with its panic payload, if any).
    fn complete(&self, payload: Option<Box<dyn std::any::Any + Send>>) {
        if let Some(p) = payload {
            let mut slot = self.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(p);
            }
        }
        let mut rem = self.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut rem = self.remaining.lock().unwrap();
        while *rem > 0 {
            rem = self.done.wait(rem).unwrap();
        }
    }
}

struct Inbox {
    tasks: VecDeque<(StaticTask, Arc<RunState>)>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Inbox>,
    available: Condvar,
}

fn exec(task: StaticTask, state: &RunState) {
    let result = catch_unwind(AssertUnwindSafe(move || task()));
    state.complete(result.err());
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.tasks.pop_front() {
                    break Some(j);
                }
                if q.shutdown {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match job {
            Some((task, state)) => exec(task, &state),
            None => break,
        }
    }
    LIVE_WORKERS.fetch_sub(1, Ordering::SeqCst);
}

/// A persistent pool of `shards - 1` worker threads plus the calling
/// thread, executing per-shard task batches with scoped-borrow
/// semantics. `shards = 1` is a true no-op pool: zero threads, zero
/// queue traffic, every `run` executes inline.
pub struct ShardPool {
    shards: usize,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ShardPool {
    /// Build a pool for `shards` parallel lanes (clamped to >= 1).
    /// Spawns `shards - 1` workers — the `run` caller is the last lane.
    pub fn new(shards: usize) -> Arc<ShardPool> {
        let shards = shards.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Inbox { tasks: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
        });
        let mut workers = Vec::with_capacity(shards - 1);
        for i in 0..shards - 1 {
            let sh = shared.clone();
            THREADS_SPAWNED.fetch_add(1, Ordering::SeqCst);
            LIVE_WORKERS.fetch_add(1, Ordering::SeqCst);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("qafel-shard-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawning shard worker"),
            );
        }
        Arc::new(ShardPool { shards, shared, workers })
    }

    /// A single-lane pool (no threads; `run` executes inline).
    pub fn sequential() -> Arc<ShardPool> {
        ShardPool::new(1)
    }

    /// Parallel lanes S (worker threads + the caller).
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Worker threads owned by this pool (`shards - 1`).
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Execute every task, blocking until all have completed. Tasks may
    /// borrow from the caller's stack (disjoint `&mut` sub-slices); the
    /// caller thread executes tasks alongside the workers. If any task
    /// panicked, the first payload is re-raised here — after the whole
    /// batch has drained, so no borrow outlives the call and the pool
    /// remains usable.
    // the transmute below erases only the task lifetime; clippy compares
    // region-erased types and would call it a self-transmute
    #[allow(clippy::useless_transmute)]
    pub fn run<'a>(&self, tasks: Vec<Task<'a>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        BATCHES_RUN.fetch_add(1, Ordering::Relaxed);
        TASKS_RUN.fetch_add(n, Ordering::Relaxed);
        if self.workers.is_empty() || n == 1 {
            for t in tasks {
                t();
            }
            return;
        }
        let state = Arc::new(RunState::new(n));
        {
            let mut q = self.shared.queue.lock().unwrap();
            for t in tasks {
                // SAFETY: `run` blocks on `state.wait()` below until every
                // task has completed (panic path included), so the 'a
                // borrows captured by the task are live for its whole
                // execution. The transmute only erases that lifetime.
                let t: StaticTask = unsafe { std::mem::transmute::<Task<'a>, StaticTask>(t) };
                q.tasks.push_back((t, state.clone()));
            }
        }
        self.shared.available.notify_all();
        // The caller is a full lane: drain tasks alongside the workers.
        loop {
            let job = self.shared.queue.lock().unwrap().tasks.pop_front();
            match job {
                Some((task, st)) => exec(task, &st),
                None => break,
            }
        }
        state.wait();
        let payload = state.panic.lock().unwrap().take();
        if let Some(p) = payload {
            resume_unwind(p);
        }
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.available.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_tasks_on_disjoint_borrows() {
        let pool = ShardPool::new(4);
        let mut data = vec![0u64; 1000];
        let span = 256;
        let tasks: Vec<Task<'_>> = data
            .chunks_mut(span)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (i * span + j) as u64;
                    }
                }) as Task<'_>
            })
            .collect();
        pool.run(tasks);
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn single_lane_pool_spawns_nothing_and_runs_inline() {
        let spawned = threads_spawned_total();
        let pool = ShardPool::sequential();
        assert_eq!(pool.workers(), 0);
        assert_eq!(pool.shards(), 1);
        let mut hit = false;
        pool.run(vec![Box::new(|| hit = true) as Task<'_>]);
        assert!(hit);
        // other tests may spawn pools concurrently, so only assert this
        // pool contributed nothing (no workers => inline execution)
        let _ = spawned;
    }

    #[test]
    fn reuse_across_many_batches_is_correct() {
        let pool = ShardPool::new(3);
        let mut acc = vec![0u64; 300];
        for round in 0..200u64 {
            let tasks: Vec<Task<'_>> = acc
                .chunks_mut(100)
                .map(|chunk| {
                    Box::new(move || {
                        for v in chunk.iter_mut() {
                            *v += round;
                        }
                    }) as Task<'_>
                })
                .collect();
            pool.run(tasks);
        }
        let want: u64 = (0..200).sum();
        assert!(acc.iter().all(|&v| v == want));
    }

    #[test]
    fn panic_propagates_batch_completes_pool_survives() {
        let pool = ShardPool::new(4);
        let flags: Vec<std::sync::atomic::AtomicBool> =
            (0..4).map(|_| std::sync::atomic::AtomicBool::new(false)).collect();
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Task<'_>> = flags
                .iter()
                .enumerate()
                .map(|(i, f)| {
                    Box::new(move || {
                        if i == 2 {
                            panic!("shard boom");
                        }
                        f.store(true, Ordering::SeqCst);
                    }) as Task<'_>
                })
                .collect();
            pool.run(tasks);
        }));
        let msg = match result {
            Err(p) => *p.downcast::<&'static str>().unwrap(),
            Ok(()) => panic!("expected the shard panic to propagate"),
        };
        assert_eq!(msg, "shard boom");
        // non-panicking tasks of the same batch all ran
        for (i, f) in flags.iter().enumerate() {
            assert_eq!(f.load(Ordering::SeqCst), i != 2, "task {i}");
        }
        // the pool still works after a panic
        let mut v = vec![0u32; 4];
        let tasks: Vec<Task<'_>> = v
            .chunks_mut(1)
            .map(|c| Box::new(move || c[0] = 7) as Task<'_>)
            .collect();
        pool.run(tasks);
        assert_eq!(v, vec![7, 7, 7, 7]);
    }

    #[test]
    fn drop_joins_and_releases_workers() {
        let pool = ShardPool::new(5);
        assert_eq!(pool.workers(), 4);
        let weak = Arc::downgrade(&pool.shared);
        drop(pool);
        // drop joined every worker, so no thread still holds the queue
        assert!(weak.upgrade().is_none(), "a worker outlived the pool");
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let pool = ShardPool::new(2);
        pool.run(Vec::new());
    }

    #[test]
    fn run_counters_track_batches_and_tasks() {
        let pool = ShardPool::new(2);
        let b0 = batches_run_total();
        let t0 = tasks_run_total();
        let mut v = vec![0u8; 3];
        let tasks: Vec<Task<'_>> =
            v.chunks_mut(1).map(|c| Box::new(move || c[0] = 1) as Task<'_>).collect();
        pool.run(tasks);
        pool.run(Vec::new()); // empty batches don't count
        // >= because other tests drive pools concurrently
        assert!(batches_run_total() >= b0 + 1);
        assert!(tasks_run_total() >= t0 + 3);
    }
}
