//! Foundation utilities built from scratch (the offline build environment
//! provides no `rand`, `serde`, or similar crates): deterministic PRNG,
//! probability distributions, descriptive statistics, bit-level I/O and a
//! JSON parser/writer.

pub mod bitio;
pub mod dist;
pub mod json;
pub mod pool;
pub mod prng;
pub mod shard;
pub mod stats;
pub mod vecf;

pub use prng::Prng;
