//! Flat f32 vector kernels for the coordinator hot path.
//!
//! The whole stack treats model parameters as an opaque `f32[d]` vector
//! (d ≈ 29.5k for the paper's model); these routines are the only math
//! the L3 server performs per update, so they are written to autovectorize
//! (simple indexed loops over slices of equal, asserted length).

/// y += x
#[inline]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for i in 0..y.len() {
        y[i] += x[i];
    }
}

/// y += a * x (axpy)
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for i in 0..y.len() {
        y[i] += a * x[i];
    }
}

/// y = a * y
#[inline]
pub fn scale(y: &mut [f32], a: f32) {
    for v in y.iter_mut() {
        *v *= a;
    }
}

/// out = a - b
#[inline]
pub fn sub(out: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(out.len(), a.len());
    assert_eq!(a.len(), b.len());
    for i in 0..out.len() {
        out[i] = a[i] - b[i];
    }
}

/// l2 norm (f64 accumulation for stability at d ~ 3e4).
#[inline]
pub fn norm2(x: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for &v in x {
        acc += (v as f64) * (v as f64);
    }
    acc.sqrt()
}

/// squared l2 distance between two vectors.
#[inline]
pub fn dist2_sq(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        let d = (a[i] - b[i]) as f64;
        acc += d * d;
    }
    acc
}

/// dot product (f64 accumulation).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        acc += a[i] as f64 * b[i] as f64;
    }
    acc
}

/// Set all elements to zero without reallocating.
#[inline]
pub fn zero(y: &mut [f32]) {
    for v in y.iter_mut() {
        *v = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_ops() {
        let mut y = vec![1.0, 2.0, 3.0];
        add_assign(&mut y, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![2.0, 3.0, 4.0]);
        axpy(&mut y, 2.0, &[1.0, 0.0, -1.0]);
        assert_eq!(y, vec![4.0, 3.0, 2.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![2.0, 1.5, 1.0]);
        let mut out = vec![0.0; 3];
        sub(&mut out, &[3.0, 3.0, 3.0], &y);
        assert_eq!(out, vec![1.0, 1.5, 2.0]);
        zero(&mut out);
        assert_eq!(out, vec![0.0; 3]);
    }

    #[test]
    fn norms_and_dots() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert!((dot(&[1.0, 2.0], &[3.0, 4.0]) - 11.0).abs() < 1e-12);
        assert!((dist2_sq(&[0.0, 0.0], &[3.0, 4.0]) - 25.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        let mut y = vec![0.0; 2];
        add_assign(&mut y, &[1.0]);
    }
}
