//! Shard-range arithmetic for the parallel aggregation pipeline.
//!
//! A `d`-dimensional vector is split into up to `S` contiguous shards of
//! equal span, where the span is rounded **up** to a codec alignment (for
//! qsgd: the bucket size, so per-bucket norms stay shard-local and the
//! bit-packed body stays byte-aligned at shard seams). The last shard
//! absorbs the ragged tail. `slice::chunks(span)` / `chunks_mut(span)`
//! then produce exactly these shards.

/// Shard span for dimension `d`, at most `shards` shards, aligned up to
/// `align` coordinates. Always >= 1; `span >= d` means "don't shard".
pub fn span_for(d: usize, shards: usize, align: usize) -> usize {
    let shards = shards.max(1);
    let align = align.max(1);
    let raw = d.div_ceil(shards).max(1);
    raw.div_ceil(align) * align
}

/// The shard ranges `chunks(span)` will produce (for tests/diagnostics).
pub fn ranges(d: usize, shards: usize, align: usize) -> Vec<std::ops::Range<usize>> {
    let span = span_for(d, shards, align);
    let mut out = Vec::new();
    let mut lo = 0;
    while lo < d {
        let hi = (lo + span).min(d);
        out.push(lo..hi);
        lo = hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_cover_exactly_and_align() {
        for d in [1usize, 7, 128, 129, 1000, 29_474, 1 << 20] {
            for shards in [1usize, 2, 3, 4, 8, 16] {
                for align in [1usize, 8, 128] {
                    let span = span_for(d, shards, align);
                    assert!(span >= 1);
                    assert_eq!(span % align, 0, "span {span} not {align}-aligned");
                    let rs = ranges(d, shards, align);
                    assert!(rs.len() <= shards.max(1), "{d}/{shards}/{align}: {} ranges", rs.len());
                    assert_eq!(rs.first().map(|r| r.start), Some(0));
                    assert_eq!(rs.last().map(|r| r.end), Some(d));
                    for w in rs.windows(2) {
                        assert_eq!(w[0].end, w[1].start);
                        assert_eq!(w[0].start % align, 0);
                    }
                }
            }
        }
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(span_for(0, 4, 128), 128); // span >= 1, no ranges
        assert!(ranges(0, 4, 128).is_empty());
        assert_eq!(span_for(10, 1, 1), 10);
        assert_eq!(ranges(10, 1, 1), vec![0..10]);
    }
}
