//! Probability distributions used by the simulator and experiments.
//!
//! The paper (Appendix D, following FedBuff's Appendix C) models client
//! training durations as a **half-normal** |N(0, sigma^2)| — "the most
//! accurate representation of the delay distribution observed in Meta's
//! production FL system" — and client arrivals at a **constant rate**.
//! We also provide exponential arrivals and log-normal durations for
//! ablations.

use super::prng::Prng;

/// Standard normal via Box–Muller (polar/Marsaglia variant to avoid
/// trig), with the spare value cached.
#[derive(Clone, Debug, Default)]
pub struct Normal {
    spare: Option<f64>,
}

impl Normal {
    pub fn new() -> Self {
        Normal { spare: None }
    }

    /// One N(0,1) sample.
    pub fn sample(&mut self, rng: &mut Prng) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = 2.0 * rng.f64() - 1.0;
            let v = 2.0 * rng.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * m);
                return u * m;
            }
        }
    }

    /// One N(mu, sigma^2) sample.
    pub fn sample_with(&mut self, rng: &mut Prng, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.sample(rng)
    }
}

/// Half-normal |N(0, sigma^2)|: the paper's training-duration model.
///
/// Mean is `sigma * sqrt(2/pi)`; the paper derives its arrival rates for
/// concurrency targets from this expectation (Appendix D).
#[derive(Clone, Debug)]
pub struct HalfNormal {
    pub sigma: f64,
    normal: Normal,
}

impl HalfNormal {
    pub fn new(sigma: f64) -> Self {
        HalfNormal { sigma, normal: Normal::new() }
    }

    pub fn sample(&mut self, rng: &mut Prng) -> f64 {
        (self.normal.sample(rng) * self.sigma).abs()
    }

    /// E[|N(0, sigma^2)|] = sigma * sqrt(2/pi).
    pub fn mean(&self) -> f64 {
        self.sigma * (2.0 / std::f64::consts::PI).sqrt()
    }

    /// The constant client arrival rate that sustains a target expected
    /// concurrency: `rate = concurrency / E[duration]`. With sigma = 1 this
    /// reproduces the paper's 125 / 627 / 1253 clients-per-unit-time for
    /// concurrencies 100 / 500 / 1000.
    pub fn rate_for_concurrency(&self, concurrency: f64) -> f64 {
        concurrency / self.mean()
    }
}

/// Exponential(rate) — Poisson inter-arrival ablation.
#[derive(Clone, Copy, Debug)]
pub struct Exponential {
    pub rate: f64,
}

impl Exponential {
    pub fn new(rate: f64) -> Self {
        assert!(rate > 0.0);
        Exponential { rate }
    }

    pub fn sample(&self, rng: &mut Prng) -> f64 {
        // -ln(1-u)/rate; 1-u in (0,1] avoids ln(0).
        -(1.0 - rng.f64()).ln() / self.rate
    }
}

/// Log-normal duration ablation (heavier tail than half-normal).
#[derive(Clone, Debug)]
pub struct LogNormal {
    pub mu: f64,
    pub sigma: f64,
    normal: Normal,
}

impl LogNormal {
    pub fn new(mu: f64, sigma: f64) -> Self {
        LogNormal { mu, sigma, normal: Normal::new() }
    }

    pub fn sample(&mut self, rng: &mut Prng) -> f64 {
        (self.mu + self.sigma * self.normal.sample(rng)).exp()
    }
}

/// Client training-duration models (paper default: HalfNormal(1)).
#[derive(Clone, Debug)]
pub enum DurationDist {
    HalfNormal(HalfNormal),
    LogNormal(LogNormal),
    /// Deterministic duration (unit tests / degenerate ablation).
    Fixed(f64),
}

impl DurationDist {
    pub fn sample(&mut self, rng: &mut Prng) -> f64 {
        match self {
            DurationDist::HalfNormal(h) => h.sample(rng),
            DurationDist::LogNormal(l) => l.sample(rng),
            DurationDist::Fixed(v) => *v,
        }
    }

    pub fn mean(&self) -> f64 {
        match self {
            DurationDist::HalfNormal(h) => h.mean(),
            DurationDist::LogNormal(l) => (l.mu + 0.5 * l.sigma * l.sigma).exp(),
            DurationDist::Fixed(v) => *v,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_moments() {
        let mut rng = Prng::new(1);
        let mut n = Normal::new();
        let cnt = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..cnt {
            let x = n.sample(&mut rng);
            sum += x;
            sq += x * x;
        }
        let mean = sum / cnt as f64;
        let var = sq / cnt as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn half_normal_mean_matches_formula() {
        let mut rng = Prng::new(2);
        let mut h = HalfNormal::new(1.0);
        let cnt = 200_000;
        let mut sum = 0.0;
        for _ in 0..cnt {
            let x = h.sample(&mut rng);
            assert!(x >= 0.0);
            sum += x;
        }
        let mean = sum / cnt as f64;
        assert!((mean - h.mean()).abs() < 0.01, "{mean} vs {}", h.mean());
    }

    #[test]
    fn paper_arrival_rates() {
        // Appendix D: concurrencies 100/500/1000 <- rates 125/627/1253.
        let h = HalfNormal::new(1.0);
        assert_eq!(h.rate_for_concurrency(100.0).round() as i64, 125);
        assert_eq!(h.rate_for_concurrency(500.0).round() as i64, 627);
        assert_eq!(h.rate_for_concurrency(1000.0).round() as i64, 1253);
    }

    #[test]
    fn duration_dist_means_are_exact_per_distribution() {
        // The scenario engine calibrates rate = concurrency / E[D] from
        // these means. E[lognormal(0,1)] = e^0.5 ~ 1.65 vs
        // E[|N(0,1)|] ~ 0.80: reusing the half-normal mean for lognormal
        // durations (the pre-scenario engine's bug) overshoots achieved
        // concurrency by ~2x.
        let hn = DurationDist::HalfNormal(HalfNormal::new(1.0));
        let ln = DurationDist::LogNormal(LogNormal::new(0.0, 1.0));
        let fx = DurationDist::Fixed(2.0);
        assert!((hn.mean() - (2.0 / std::f64::consts::PI).sqrt()).abs() < 1e-15);
        assert!((ln.mean() - 0.5f64.exp()).abs() < 1e-12);
        assert_eq!(fx.mean(), 2.0);
        assert_eq!((100.0 / hn.mean()).round() as i64, 125); // paper rate
        let ratio = ln.mean() / hn.mean();
        assert!(ratio > 2.0, "miscalibration factor {ratio}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Prng::new(3);
        let e = Exponential::new(4.0);
        let cnt = 100_000;
        let mut sum = 0.0;
        for _ in 0..cnt {
            sum += e.sample(&mut rng);
        }
        assert!((sum / cnt as f64 - 0.25).abs() < 0.01);
    }

    #[test]
    fn lognormal_positive() {
        let mut rng = Prng::new(4);
        let mut l = LogNormal::new(0.0, 0.5);
        for _ in 0..1000 {
            assert!(l.sample(&mut rng) > 0.0);
        }
    }
}
