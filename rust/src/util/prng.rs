//! Deterministic pseudo-random number generation.
//!
//! All stochastic behaviour in the system (client arrivals, training
//! durations, batch sampling, quantizer randomness, baseline noise) flows
//! from a single master seed through *named streams*, so every experiment
//! is exactly reproducible and independent randomness sources never alias.
//!
//! Generator: xoshiro256++ (Blackman & Vigna), seeded via SplitMix64 —
//! the standard construction recommended by the authors. Not
//! cryptographic; statistical quality is what matters here.

/// SplitMix64 step: used for seeding and cheap stream derivation.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Create a generator from a 64-bit seed (expanded with SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // xoshiro must not be seeded with all zeros; splitmix cannot
        // produce four consecutive zeros, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Prng { s }
    }

    /// The raw xoshiro256++ state, for checkpointing. Restoring with
    /// [`Prng::from_state`] continues the exact sequence.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a state captured by [`Prng::state`].
    /// The all-zero state is invalid for xoshiro and is rejected by
    /// nudging it to the same guard value [`Prng::new`] uses.
    pub fn from_state(s: [u64; 4]) -> Prng {
        let mut s = s;
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Prng { s }
    }

    /// Derive an independent generator for a named sub-stream.
    ///
    /// Mixes the stream label into the seed with SplitMix64 so that e.g.
    /// the "arrivals" and "durations" streams of the same experiment are
    /// decorrelated, and so that per-entity streams (`stream_u64(id)`)
    /// never collide with each other.
    pub fn stream(&self, label: &str) -> Prng {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a offset basis
        for b in label.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        let mut mix = self.s[0] ^ h;
        let _ = splitmix64(&mut mix);
        Prng::new(mix)
    }

    /// Derive an independent generator keyed by an integer (client id,
    /// round number, ...).
    pub fn stream_u64(&self, key: u64) -> Prng {
        let mut mix = self.s[1] ^ key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let _ = splitmix64(&mut mix);
        Prng::new(mix ^ self.s[2])
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1) with 24 bits of precision.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi) (half-open range).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a slice with iid U[0,1) f32 values (quantizer noise).
    pub fn fill_uniform_f32(&mut self, out: &mut [f32]) {
        // Unroll two lanes per u64 for throughput in the hot quant path.
        let mut chunks = out.chunks_exact_mut(2);
        for pair in &mut chunks {
            let bits = self.next_u64();
            pair[0] = (bits >> 40) as f32 * (1.0 / (1u64 << 24) as f32);
            pair[1] = ((bits >> 8) & 0xFF_FFFF) as f32 * (1.0 / (1u64 << 24) as f32);
        }
        for v in chunks.into_remainder() {
            *v = self.f32();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (floyd's algorithm for
    /// small k, shuffle prefix otherwise).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        // Floyd: guarantees distinctness in O(k) expected time.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j as u64 + 1) as usize;
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_continues_the_sequence() {
        let mut a = Prng::new(1234);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Prng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // the all-zero state is nudged, not accepted verbatim
        let mut z = Prng::from_state([0, 0, 0, 0]);
        assert_ne!(z.next_u64(), 0);
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn streams_are_decorrelated() {
        let root = Prng::new(7);
        let mut s1 = root.stream("arrivals");
        let mut s2 = root.stream("durations");
        let mut s1b = root.stream("arrivals");
        assert_eq!(s1.next_u64(), s1b.next_u64());
        let same = (0..64).filter(|_| s1.next_u64() == s2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval_and_uniform() {
        let mut g = Prng::new(3);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = g.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut g = Prng::new(9);
        let mut counts = [0usize; 3];
        let n = 90_000;
        for _ in 0..n {
            counts[g.below(3) as usize] += 1;
        }
        for c in counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 1.0 / 3.0).abs() < 0.01, "frac {frac}");
        }
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut g = Prng::new(5);
        for (n, k) in [(10, 10), (100, 3), (50, 25), (1, 1)] {
            let idx = g.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), k);
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn fill_uniform_f32_matches_bounds() {
        let mut g = Prng::new(11);
        let mut buf = vec![0f32; 1001];
        g.fill_uniform_f32(&mut buf);
        assert!(buf.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean: f32 = buf.iter().sum::<f32>() / buf.len() as f32;
        assert!((mean - 0.5).abs() < 0.05);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = Prng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        g.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
