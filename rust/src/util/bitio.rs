//! Bit-level writer/reader used by the quantizer wire codecs.
//!
//! The paper's communication metrics (kB/upload, kB/download) are computed
//! from the length of the *actual packed buffers* produced here — not from
//! formulas — so correctness and density of the packing directly affects
//! the reproduced tables.

/// Append-only bit buffer, LSB-first within each byte.
#[derive(Clone, Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Number of valid bits in the last byte (0 => byte-aligned).
    used: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        BitWriter { buf: Vec::new(), used: 0 }
    }

    pub fn with_capacity(bits: usize) -> Self {
        BitWriter { buf: Vec::with_capacity(bits.div_ceil(8)), used: 0 }
    }

    /// Write the low `n` bits of `v` (n <= 57; keeps the fast path
    /// branch-free by staging through a u64 window).
    #[inline]
    pub fn write(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 57, "write up to 57 bits at a time");
        debug_assert!(n == 64 || v < (1u64 << n), "value {v} overflows {n} bits");
        let mut acc = v;
        let mut left = n;
        if self.used > 0 {
            let last = self.buf.len() - 1;
            let space = 8 - self.used;
            let take = left.min(space);
            let mask = (1u64 << take) - 1;
            self.buf[last] |= ((acc & mask) as u8) << self.used;
            acc >>= take;
            left -= take;
            self.used = (self.used + take) & 7;
        }
        while left >= 8 {
            self.buf.push(acc as u8);
            acc >>= 8;
            left -= 8;
        }
        if left > 0 {
            self.buf.push((acc & ((1 << left) - 1)) as u8);
            self.used = left;
        }
    }

    /// Write a single bit.
    #[inline]
    pub fn write_bit(&mut self, b: bool) {
        self.write(b as u64, 1);
    }

    /// Write a full f32 (bit pattern, 32 bits).
    #[inline]
    pub fn write_f32(&mut self, v: f32) {
        self.write(v.to_bits() as u64, 32);
    }

    /// Write a u32 (32 bits).
    #[inline]
    pub fn write_u32(&mut self, v: u32) {
        self.write(v as u64, 32);
    }

    /// Total bits written.
    pub fn bit_len(&self) -> usize {
        if self.used == 0 {
            self.buf.len() * 8
        } else {
            (self.buf.len() - 1) * 8 + self.used as usize
        }
    }

    /// Finish and return the byte buffer (zero-padded to a byte boundary).
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len_bytes(&self) -> usize {
        self.buf.len()
    }
}

/// Reader over a bit buffer produced by [`BitWriter`].
#[derive(Clone, Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Absolute bit cursor.
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    /// Read `n` bits (n <= 57). Returns None at end of buffer.
    #[inline]
    pub fn read(&mut self, n: u32) -> Option<u64> {
        let end = self.pos + n as usize;
        if end > self.buf.len() * 8 {
            return None;
        }
        let mut out = 0u64;
        let mut got = 0u32;
        let mut pos = self.pos;
        while got < n {
            let byte = self.buf[pos / 8] as u64;
            let off = (pos % 8) as u32;
            let avail = 8 - off;
            let take = (n - got).min(avail);
            let bits = (byte >> off) & ((1u64 << take) - 1);
            out |= bits << got;
            got += take;
            pos += take as usize;
        }
        self.pos = end;
        Some(out)
    }

    #[inline]
    pub fn read_bit(&mut self) -> Option<bool> {
        self.read(1).map(|b| b != 0)
    }

    /// Position the cursor at an absolute bit offset (reads past the end
    /// simply return `None`). Fixed-width record codecs (top_k's
    /// `index:value` entries) use this for random access / binary search.
    #[inline]
    pub fn seek(&mut self, bit: usize) {
        self.pos = bit;
    }

    #[inline]
    pub fn read_f32(&mut self) -> Option<f32> {
        self.read(32).map(|b| f32::from_bits(b as u32))
    }

    #[inline]
    pub fn read_u32(&mut self) -> Option<u32> {
        self.read(32).map(|b| b as u32)
    }

    pub fn remaining_bits(&self) -> usize {
        (self.buf.len() * 8).saturating_sub(self.pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn roundtrip_mixed_widths() {
        let mut w = BitWriter::new();
        w.write(0b101, 3);
        w.write_bit(true);
        w.write(0xDEAD, 16);
        w.write_f32(3.5);
        w.write(0x1FF, 9);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3), Some(0b101));
        assert_eq!(r.read_bit(), Some(true));
        assert_eq!(r.read(16), Some(0xDEAD));
        assert_eq!(r.read_f32(), Some(3.5));
        assert_eq!(r.read(9), Some(0x1FF));
    }

    #[test]
    fn random_roundtrip() {
        let mut rng = Prng::new(77);
        for _ in 0..50 {
            let mut vals = Vec::new();
            let mut w = BitWriter::new();
            for _ in 0..500 {
                let n = 1 + rng.below(57) as u32;
                let v = rng.next_u64() & ((1u64 << n) - 1).max(1);
                let v = if n == 64 { v } else { v & ((1u64 << n) - 1) };
                w.write(v, n);
                vals.push((v, n));
            }
            let bit_len = w.bit_len();
            let bytes = w.into_bytes();
            assert!(bytes.len() * 8 - bit_len < 8);
            let mut r = BitReader::new(&bytes);
            for (v, n) in vals {
                assert_eq!(r.read(n), Some(v));
            }
        }
    }

    #[test]
    fn read_past_end_is_none() {
        let mut w = BitWriter::new();
        w.write(0b11, 2);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(2), Some(0b11));
        // padding bits exist up to the byte boundary, but not beyond
        assert!(r.read(7).is_none());
    }

    #[test]
    fn sixteen_bit_symbols_cross_byte_boundaries() {
        // 16-bit symbols (the widest qsgd level width) written at every
        // possible bit phase: pre-pad with 0..8 bits so symbols straddle
        // byte boundaries in all alignments, and verify exact roundtrip.
        for phase in 0u32..8 {
            let mut w = BitWriter::new();
            if phase > 0 {
                w.write(0b1010_1010 & ((1 << phase) - 1), phase);
            }
            let vals: Vec<u64> = (0..100u64).map(|i| (i * 0x9E37) & 0xFFFF).collect();
            for &v in &vals {
                w.write(v, 16);
            }
            assert_eq!(w.bit_len(), phase as usize + 16 * vals.len());
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            if phase > 0 {
                r.read(phase).unwrap();
            }
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(r.read(16), Some(v), "phase {phase}, symbol {i}");
            }
        }
        // extreme values survive
        let mut w = BitWriter::new();
        w.write(0xFFFF, 16);
        w.write(0, 16);
        w.write(0x8001, 16);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(16), Some(0xFFFF));
        assert_eq!(r.read(16), Some(0));
        assert_eq!(r.read(16), Some(0x8001));
    }

    #[test]
    fn truncated_buffer_reads_return_none_not_garbage() {
        let mut w = BitWriter::new();
        for i in 0..10u64 {
            w.write(i, 16);
        }
        let bytes = w.into_bytes();
        // drop the final byte: the 10th symbol is half gone
        let cut = &bytes[..bytes.len() - 1];
        let mut r = BitReader::new(cut);
        for i in 0..9u64 {
            assert_eq!(r.read(16), Some(i));
        }
        assert_eq!(r.remaining_bits(), 8);
        assert_eq!(r.read(16), None, "partial symbol must not decode");
        // the cursor does not advance on a failed read
        assert_eq!(r.remaining_bits(), 8);
        assert_eq!(r.read(8), Some(9)); // low byte of the 10th symbol
        assert_eq!(r.read(1), None);
        // empty buffer
        let mut empty = BitReader::new(&[]);
        assert_eq!(empty.read(1), None);
        assert_eq!(empty.read_f32(), None);
        assert_eq!(empty.remaining_bits(), 0);
    }

    #[test]
    fn seek_random_access_matches_sequential_reads() {
        // fixed-width records (the top_k entry layout): seeking to entry
        // j reads the same bits a sequential scan would
        let mut w = BitWriter::new();
        w.write_u32(10);
        for j in 0..10u64 {
            w.write(j * 3 + 1, 15);
            w.write_f32(j as f32 * 0.5);
        }
        let bytes = w.into_bytes();
        for j in (0..10usize).rev() {
            let mut r = BitReader::new(&bytes);
            r.seek(32 + j * 47);
            assert_eq!(r.read(15), Some(j as u64 * 3 + 1), "entry {j}");
            assert_eq!(r.read_f32(), Some(j as f32 * 0.5), "entry {j}");
        }
        // seeking past the end yields None, not garbage
        let mut r = BitReader::new(&bytes);
        r.seek(bytes.len() * 8 - 3);
        assert_eq!(r.read(15), None);
    }

    #[test]
    fn bit_len_tracks_exactly() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.write_bit(true);
        assert_eq!(w.bit_len(), 1);
        w.write(0, 8);
        assert_eq!(w.bit_len(), 9);
        w.write(0, 55);
        assert_eq!(w.bit_len(), 64);
    }
}
