//! Property-based test runner.
//!
//! Usage:
//! ```ignore
//! forall("codec roundtrip", gens::vec_f32(0..4096, -1e3..1e3), |xs| {
//!     let enc = encode(xs);
//!     let dec = decode(&enc)?;
//!     ensure(dec == *xs, "mismatch")
//! });
//! ```

use crate::util::prng::Prng;

/// Generator: produce a case from randomness.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Prng) -> T;
}

impl<T, F: Fn(&mut Prng) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Prng) -> T {
        self(rng)
    }
}

/// Types that can propose smaller versions of themselves for shrinking.
pub trait Shrink: Sized {
    /// Candidate strictly-"smaller" values, most aggressive first.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - 1]
        }
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - 1]
        }
    }
}

impl Shrink for f32 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0.0 {
            vec![]
        } else {
            vec![0.0, self / 2.0]
        }
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // halve the vector
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        // drop one element
        if self.len() > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
        }
        // shrink the first element
        if let Some(first_shrunk) = self[0].shrink().into_iter().next() {
            let mut v = self.clone();
            v[0] = first_shrunk;
            out.push(v);
        }
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Runner configuration.
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        let seed = std::env::var("QAFEL_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x5EED_CAFE);
        PropConfig { cases: 100, seed, max_shrink_steps: 200 }
    }
}

/// Run `prop` over `cases` generated inputs; panic with the minimal
/// failing case on violation.
pub fn forall<T, G, P>(name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone + Shrink,
    G: Gen<T>,
    P: Fn(&T) -> Result<(), String>,
{
    forall_cfg(name, PropConfig::default(), gen, prop)
}

/// Like [`forall`] with explicit configuration.
pub fn forall_cfg<T, G, P>(name: &str, cfg: PropConfig, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone + Shrink,
    G: Gen<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Prng::new(cfg.seed).stream(name);
    for case in 0..cfg.cases {
        let input = gen.generate(&mut rng);
        if let Err(msg) = prop(&input) {
            // shrink
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: loop {
                for candidate in best.shrink() {
                    steps += 1;
                    if steps > cfg.max_shrink_steps {
                        break 'outer;
                    }
                    if let Err(m) = prop(&candidate) {
                        best = candidate;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed at case {case} (seed {}):\n  {}\n  minimal input: {:?}",
                cfg.seed, best_msg, best
            );
        }
    }
}

/// Ready-made generators.
pub mod gens {
    use super::*;

    /// Vec<f32> with length in [lo_len, hi_len) and values in [lo, hi).
    pub fn vec_f32(
        lo_len: usize,
        hi_len: usize,
        lo: f32,
        hi: f32,
    ) -> impl Gen<Vec<f32>> {
        move |rng: &mut Prng| {
            let n = rng.range(lo_len, hi_len.max(lo_len + 1));
            (0..n).map(|_| lo + (hi - lo) * rng.f32()).collect()
        }
    }

    /// Vec<f32> with occasional special values (0, subnormal-ish, large).
    pub fn vec_f32_gnarly(lo_len: usize, hi_len: usize) -> impl Gen<Vec<f32>> {
        move |rng: &mut Prng| {
            let n = rng.range(lo_len, hi_len.max(lo_len + 1));
            (0..n)
                .map(|_| match rng.below(10) {
                    0 => 0.0,
                    1 => 1e-30,
                    2 => -1e30,
                    3 => 1e30,
                    _ => (rng.f32() - 0.5) * 2e3,
                })
                .collect()
        }
    }

    pub fn usize_in(lo: usize, hi: usize) -> impl Gen<usize> {
        move |rng: &mut Prng| rng.range(lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::RefCell::new(&mut count);
        forall_cfg(
            "sum is commutative",
            PropConfig { cases: 50, ..Default::default() },
            gens::vec_f32(0, 20, -10.0, 10.0),
            |xs| {
                **counter.borrow_mut() += 1;
                let a: f32 = xs.iter().sum();
                let b: f32 = xs.iter().rev().sum();
                if (a - b).abs() <= 1e-3 {
                    Ok(())
                } else {
                    Err(format!("{a} != {b}"))
                }
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_shrunk_input() {
        forall(
            "always fails",
            gens::vec_f32(5, 30, 1.0, 2.0),
            |_xs| Err("nope".to_string()),
        );
    }

    #[test]
    fn shrinker_minimizes_vec_len() {
        // property: vectors shorter than 3 pass. shrinker should find len 3.
        let result = std::panic::catch_unwind(|| {
            forall(
                "short vectors pass",
                gens::vec_f32(10, 20, 0.0, 1.0),
                |xs| {
                    if xs.len() < 3 {
                        Ok(())
                    } else {
                        Err(format!("len {}", xs.len()))
                    }
                },
            );
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("expected failure"),
        };
        // minimal failing length is between 3 and 5 (shrinking is greedy,
        // not exhaustive) — must be far below the generated 10..20
        let min_len = msg
            .split("len ")
            .nth(1)
            .and_then(|s| s.split_whitespace().next())
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap();
        assert!(min_len <= 5, "shrinker stopped at {min_len}: {msg}");
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed| {
            let mut v = Vec::new();
            let c = std::cell::RefCell::new(&mut v);
            forall_cfg(
                "collect",
                PropConfig { cases: 5, seed, max_shrink_steps: 0 },
                gens::usize_in(0, 1000),
                |x| {
                    c.borrow_mut().push(*x);
                    Ok(())
                },
            );
            v
        };
        assert_eq!(collect(7), collect(7));
        assert_ne!(collect(7), collect(8));
    }
}
