//! Mini property-testing harness (no `proptest` offline).
//!
//! `forall` runs a property over N generated cases; on failure it retries
//! the case through a simple halving shrinker (for types that implement
//! [`Shrink`]) and reports the minimal failing input plus the seed needed
//! to replay the run (`QAFEL_PROP_SEED` env var).

pub mod prop;

pub use prop::{forall, forall_cfg, Gen, PropConfig, Shrink};
