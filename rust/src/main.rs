//! `qafel` — command-line entry point.
//!
//! Subcommands:
//! * `exp fig3|table1|table2|convergence|ablate|heterogeneity|robustness`
//!   — regenerate the paper's figures/tables (DESIGN.md §6) and the
//!   scenario-engine / robust-aggregation ablations into `reports/`.
//! * `run` — one simulated training run, printing the curve.
//! * `leader` / `worker` — the real TCP distributed runtime.
//! * `journal tail|replay` — inspect or bit-verify a flight-recorder
//!   journal (ARCHITECTURE.md §Telemetry).
//! * `info` — inspect an artifact manifest.
//! * `selfcheck` — cross-validate the rust qsgd codec against the L1
//!   Pallas kernel artifact, and the full PJRT round-trip.
//!
//! Common options: `--config <file.toml>`, repeated `--set a.b=v`
//! overrides, `--backend pjrt|quadratic`, `--artifacts <dir>`,
//! `--out <dir>`, `--verbose`.

use anyhow::{anyhow, bail, Result};
use qafel::runtime::Backend as _;
use qafel::cli::Args;
use qafel::config::Config;
use qafel::experiments::{self, runner::BackendFactory};
use qafel::net::{Leader, Worker};
use qafel::runtime::{artifacts_available, artifacts_dir, Engine, PjrtBackend, QuadraticBackend};
use qafel::sim::{SimEngine, SimOptions};
use std::rc::Rc;

const USAGE: &str = "\
qafel <command> [options]

commands:
  exp <fig3|table1|table2|convergence|ablate|heterogeneity|robustness>
                                                regenerate paper results
  run                                           single simulated run
  scenario calibrate TRACE.csv [--out FILE]     fit tier weights/durations
                                                from a client trace
  leader --addr HOST:PORT --workers N           TCP leader (tree root)
  leader --upstream HOST:PORT --addr HOST:PORT --workers N
                                                TCP edge leader (tree node:
                                                worker upstream, leader down)
  worker --addr HOST:PORT                       TCP worker (quadratic backend)
  journal tail FILE.jsonl                       pretty-print a run journal
  journal replay FILE.jsonl                     re-execute a journal and
                                                verify every broadcast bit
  info                                          show artifact manifest
  selfcheck                                     PJRT + Pallas cross-checks

options:
  --config FILE      TOML config (defaults = paper Appendix D)
  --set a.b=v        override one config value (repeatable)
  --backend KIND     pjrt (default when artifacts exist) | quadratic
  --artifacts DIR    artifacts directory (default: artifacts)
  --out DIR          report output directory (default: reports)
  --horizons LIST    convergence: comma-separated T values
  --which LIST       ablate: hidden-state,k-sweep,staleness,non-broadcast
  --fast             heterogeneity/robustness: tiny population smoke (CI)
  --verbose          progress logging

flight recorder (run + leader; ARCHITECTURE.md §Telemetry):
  --journal FILE     write the event-sourced run journal (JSONL)
  --checkpoint-every N  emit a resume checkpoint every N server steps
  --resume           continue a killed run from the journal's last
                     checkpoint (requires --journal; appends to it)
  --progress N       print a live progress line every N server steps
  --timings          worker: enable span timers and print a breakdown

net options (wire protocol v2, ARCHITECTURE.md; defaults from [net]):
  --addr HOST:PORT   leader listen / worker connect address
  --workers N        leader: workers to wait for
  --upstream H:PORT  run as an edge leader forwarding partial aggregates
                     to the root at H:PORT (net.edge_buffer sizes the edge
                     buffer, net.partial_codec picks Q_p)
  --report-json FILE leader: write the run report (incl. per-worker
                     codec/byte/staleness accounting) as JSON
  --tier NAME        worker: device tier announced in the Hello; leader
                     resolves scenario.tiers.NAME.quant_client
  --quant-client SPEC worker: explicit upload codec (wins over --tier)
  --bandwidth-mbps X worker: advertise uplink bandwidth in the Hello;
                     scores the leader's net.adaptive codec controller
  --v1               worker: speak the legacy v1 protocol (no Hello)
  --round-delay-ms N worker: sleep between rounds (default 5)
  --adversary SPEC   worker: corrupt every upload before quantization —
                     sign_flip | scale:<c> | stale_replay (robustness
                     drills against a live leader; [fl.robust] defends)

scenario overrides (heterogeneous populations, DESIGN_SCENARIOS.md):
  --set 'scenario.arrival=\"bursty\"'          constant | poisson | bursty
  --set 'scenario.sampling=\"availability\"'   weighted | availability
  --set scenario.tiers.slow.weight=0.8       per-tier knobs: weight, duration,
  --set scenario.tiers.slow.dropout=0.1      duration_sigma, upload_mbps,
  --set scenario.tiers.slow.day_period=24    download_mbps, dropout, day_period,
  --set 'scenario.tiers.slow.quant_client=\"top:0.05\"'   on_fraction, phase,
  --set scenario.tiers.slow.partial_work=0.5 quant_client, partial_work
  (string values keep their TOML quotes: quote the whole --set for the shell)
";

fn load_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.opt("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::default(),
    };
    for assignment in args.opts("set") {
        cfg.set(assignment)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Apply the flight-recorder flags (`--journal`, `--checkpoint-every`,
/// `--progress`) on top of the loaded config and re-validate. CLI flags
/// win over `[telemetry]` keys the same way `--addr` wins over
/// `net.addr`.
fn apply_telemetry_flags(args: &Args, cfg: &mut Config) -> Result<()> {
    if let Some(path) = args.opt("journal") {
        cfg.telemetry.journal = Some(path.to_string());
    }
    if let Some(n) = args.opt_parse::<u64>("checkpoint-every")? {
        cfg.telemetry.checkpoint_every = n;
    }
    if let Some(n) = args.opt_parse::<u64>("progress")? {
        cfg.telemetry.progress = n;
    }
    cfg.validate()?;
    Ok(())
}

/// One-line per-step stage breakdown, or nothing when spans were off.
fn print_stage_timings(st: &qafel::telemetry::StageTimings) {
    if st.steps == 0 {
        return;
    }
    let per = |ns: u64| ns as f64 / st.steps as f64 / 1000.0;
    println!(
        "  stage us/step  : accumulate {:.1}, momentum {:.1}, diff {:.1}, \
         encode {:.1}, advance {:.1} (total {:.1})",
        per(st.accumulate_ns),
        per(st.momentum_ns),
        per(st.diff_ns),
        per(st.encode_ns),
        per(st.advance_ns),
        per(st.total_ns()),
    );
}

/// Tune the analytic backend's hyperparameters (the paper's CelebA values
/// make no sense for a synthetic quadratic).
fn preset_quadratic(cfg: &mut Config) {
    cfg.fl.client_lr = 0.15;
    cfg.fl.clip_norm = 0.0;
    cfg.fl.server_lr = 1.0;
    cfg.fl.server_momentum = 0.0;
    cfg.sim.concurrency = cfg.sim.concurrency.min(50);
    cfg.sim.eval_every = 5;
    cfg.stop.target_accuracy = 0.95;
    cfg.stop.max_uploads = 100_000;
    cfg.stop.max_server_steps = 20_000;
}

enum BackendKind {
    Pjrt(Rc<Engine>),
    Quadratic,
}

fn pick_backend(args: &Args, adir: &str) -> Result<BackendKind> {
    let kind = args.opt("backend").map(|s| s.to_string()).unwrap_or_else(|| {
        if artifacts_available(adir) { "pjrt".into() } else { "quadratic".into() }
    });
    match kind.as_str() {
        "pjrt" => {
            if !artifacts_available(adir) {
                bail!("artifacts not found in '{adir}' — run `make artifacts` first");
            }
            eprintln!("[qafel] loading + compiling artifacts from {adir} ...");
            let engine = Rc::new(Engine::load_subset(
                adir,
                &["init_params", "client_update", "eval_step"],
            )?);
            eprintln!("[qafel] engine ready (d = {})", engine.d());
            Ok(BackendKind::Pjrt(engine))
        }
        "quadratic" => Ok(BackendKind::Quadratic),
        other => bail!("unknown backend '{other}'"),
    }
}

fn make_factory<'a>(
    kind: &'a BackendKind,
    cfg: &'a Config,
) -> Box<dyn Fn(u64) -> Result<Box<dyn qafel::runtime::Backend>> + 'a> {
    match kind {
        BackendKind::Pjrt(engine) => {
            let engine = engine.clone();
            Box::new(move |seed: u64| {
                Ok(Box::new(PjrtBackend::new(engine.clone(), &cfg.data, seed)?)
                    as Box<dyn qafel::runtime::Backend>)
            })
        }
        BackendKind::Quadratic => Box::new(move |seed: u64| {
            Ok(Box::new(QuadraticBackend::new(
                128,
                64,
                1.0,
                0.3,
                0.2,
                0.02,
                cfg.fl.local_steps,
                seed,
            )) as Box<dyn qafel::runtime::Backend>)
        }),
    }
}

fn cmd_exp(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .ok_or_else(|| {
            anyhow!(
                "exp needs a target: fig3|table1|table2|convergence|ablate|\
                 heterogeneity|robustness"
            )
        })?
        .clone();
    let mut cfg = load_config(args)?;
    let adir = artifacts_dir(args.opt("artifacts").unwrap_or(""));
    let kind = pick_backend(args, &adir)?;
    if matches!(kind, BackendKind::Quadratic) && args.opt("config").is_none() {
        preset_quadratic(&mut cfg);
        for assignment in args.opts("set") {
            cfg.set(assignment)?; // re-apply: explicit overrides win
        }
    }
    let out = args.opt("out").unwrap_or("reports").to_string();
    let opts = SimOptions { verbose: args.flag("verbose"), ..Default::default() };
    if matches!(which.as_str(), "heterogeneity" | "robustness") && args.flag("fast") {
        // CI smoke: tiny population, 2 tiers, single seed
        cfg.seeds.truncate(1);
        cfg.sim.concurrency = cfg.sim.concurrency.min(20);
        cfg.stop.max_server_steps = cfg.stop.max_server_steps.min(120);
        cfg.stop.max_uploads = cfg.stop.max_uploads.min(3000);
    }
    if which == "robustness" {
        // every arm runs the same fixed horizon — attacked and defended
        // runs are compared at equal step counts, not at time-to-target
        // (the attacked mean may never reach it)
        cfg.stop.target_accuracy = 2.0;
    }
    if which == "heterogeneity" && matches!(kind, BackendKind::Quadratic) {
        // the qafel+presets arm samples m-of-P partial prefixes, which
        // need P >= 2; raise it BEFORE building the backends below so
        // the quadratic rounds actually run the length the scenario
        // engine calibrates against (PJRT local_steps is pinned by the
        // artifact and left alone)
        cfg.fl.local_steps = cfg.fl.local_steps.max(2);
    }
    let factory = make_factory(&kind, &cfg);
    let factory: &BackendFactory = factory.as_ref();

    match which.as_str() {
        "fig3" => {
            let rows = experiments::fig3::run(&cfg, factory, &out, &opts)?;
            for f in experiments::fig3::findings(&rows) {
                println!("{f}");
            }
        }
        "table1" => {
            experiments::table1::run(&cfg, factory, &out, &opts)?;
        }
        "table2" => {
            experiments::table2::run(&cfg, factory, &out, &opts)?;
        }
        "convergence" => {
            if !matches!(kind, BackendKind::Quadratic) {
                bail!("convergence needs --backend quadratic (exact grad norms)");
            }
            let horizons: Vec<u64> = args
                .opt("horizons")
                .unwrap_or("50,100,200,400,800")
                .split(',')
                .map(|s| s.trim().parse().map_err(|e| anyhow!("bad horizon: {e}")))
                .collect::<Result<_>>()?;
            experiments::convergence::run(&cfg, factory, &out, &horizons)?;
        }
        "heterogeneity" => {
            experiments::heterogeneity::run(&cfg, factory, &out, &opts)?;
        }
        "robustness" => {
            experiments::robustness::run(&cfg, factory, &out, &opts)?;
        }
        "ablate" => {
            let which = args.opt("which").unwrap_or("hidden-state,k-sweep,staleness,non-broadcast");
            for name in which.split(',') {
                match name.trim() {
                    "hidden-state" => {
                        experiments::ablations::hidden_state(&cfg, factory, &out, &opts)?;
                    }
                    "k-sweep" => {
                        experiments::ablations::k_sweep(&cfg, factory, &out, &opts)?;
                    }
                    "staleness" => {
                        experiments::ablations::staleness(&cfg, factory, &out, &opts)?;
                    }
                    "non-broadcast" => {
                        let (catch_up, full) =
                            experiments::ablations::non_broadcast_cost(&cfg, factory)?;
                        println!(
                            "non-broadcast variant (Appendix B.1): mean catch-up = {:.1} kB \
                             vs FedBuff full download {:.1} kB",
                            catch_up / 1000.0,
                            full / 1000.0
                        );
                    }
                    other => bail!("unknown ablation '{other}'"),
                }
            }
        }
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    let adir = artifacts_dir(args.opt("artifacts").unwrap_or(""));
    let kind = pick_backend(args, &adir)?;
    if matches!(kind, BackendKind::Quadratic) && args.opt("config").is_none() {
        preset_quadratic(&mut cfg);
        for assignment in args.opts("set") {
            cfg.set(assignment)?;
        }
    }
    apply_telemetry_flags(args, &mut cfg)?;
    let factory = make_factory(&kind, &cfg);
    let opts = SimOptions { verbose: true, resume: args.flag("resume"), ..Default::default() };
    let seed = cfg.seeds[0];
    let backend = factory(seed)?;
    let result = SimEngine::new(&cfg, backend.as_ref(), seed).run_with(&opts)?;
    println!("\nrun complete ({:.1}s wall):", result.wall_seconds);
    println!("  fingerprint    : {}", result.fingerprint);
    println!("  algorithm      : {}", cfg.fl.algorithm.name());
    println!("  quantizers     : client {}, server {}", cfg.quant.client, cfg.quant.server);
    println!("  server steps   : {}", result.server_steps);
    println!("  uploads        : {}", result.comm.uploads);
    println!("  kB/upload      : {:.3}", result.comm.kb_per_upload());
    println!("  kB/download    : {:.3}", result.comm.kb_per_download());
    println!("  MB uploaded    : {:.2}", result.comm.upload_mb());
    println!("  MB broadcast   : {:.2}", result.comm.broadcast_mb());
    println!("  final accuracy : {:.4}", result.final_accuracy);
    print_stage_timings(&result.stage_timings);
    match result.reached {
        Some(p) => println!(
            "  reached {:.0}% at: {} uploads / {:.1} MB up / t={:.1}",
            cfg.stop.target_accuracy * 100.0,
            p.uploads,
            p.upload_mb,
            p.time
        ),
        None => println!("  target not reached"),
    }
    let sc = &result.scenario;
    // print for any explicit scenario (even one-tier populations carry
    // dropout/window/bandwidth behavior worth seeing); skip only the
    // desugared default
    if !cfg.scenario.tiers.is_empty() {
        println!(
            "\nscenario ({} tiers, mean concurrency {:.1}, peak in-flight {}, \
             peak live snapshots {}):",
            sc.tiers.len(),
            sc.mean_concurrency,
            sc.max_in_flight,
            sc.max_live_snapshots
        );
        print!("{}", sc.table());
    }
    Ok(())
}

/// `qafel scenario calibrate <trace.csv> [--out file.toml]` — fit a
/// `[scenario]` tier table (weights + duration distributions) from an
/// observed client-trace CSV (`tier,duration` rows; see
/// `scenario::calibrate`).
fn cmd_scenario(args: &Args) -> Result<()> {
    match args.positional.get(1).map(|s| s.as_str()) {
        Some("calibrate") => {}
        other => bail!(
            "scenario needs the 'calibrate' subcommand (got {:?}); \
             usage: qafel scenario calibrate <trace.csv> [--out file.toml]",
            other
        ),
    }
    let path = args
        .positional
        .get(2)
        .ok_or_else(|| anyhow!("scenario calibrate needs a trace CSV path"))?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading trace {path}: {e}"))?;
    let fitted = qafel::scenario::calibrate::fit_trace(&text)?;
    let total: usize = fitted.iter().map(|t| t.n).sum();
    eprintln!("[calibrate] {} sessions across {} tiers:", total, fitted.len());
    for t in &fitted {
        eprintln!(
            "[calibrate]   {:<16} n={:<7} weight={:.4} mean={:.4} cv={:.3} -> {}({:.4})",
            t.name, t.n, t.weight, t.mean, t.cv, t.duration, t.duration_sigma
        );
    }
    let snippet = qafel::scenario::calibrate::to_toml(&fitted);
    match args.opt("out") {
        Some(out) => {
            std::fs::write(out, &snippet)
                .map_err(|e| anyhow!("writing {out}: {e}"))?;
            eprintln!("[calibrate] wrote {out}");
        }
        None => print!("{snippet}"),
    }
    Ok(())
}

fn cmd_leader(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    apply_telemetry_flags(args, &mut cfg)?;
    let resume = args.flag("resume");
    let addr = args.opt("addr").unwrap_or(cfg.net.addr.as_str()).to_string();
    let workers: usize = args.opt_parse("workers")?.unwrap_or(cfg.net.workers);
    let report_json = args.opt("report-json").map(str::to_string);
    // --upstream (or net.upstream) turns this process into an edge
    // leader: a worker of the upstream root, a leader of its own workers
    let upstream =
        args.opt("upstream").map(str::to_string).or_else(|| cfg.net.upstream.clone());
    if let Some(up) = upstream {
        if cfg.telemetry.journal.is_some() || resume {
            bail!(
                "--journal/--resume apply to the root leader only; edge nodes \
                 forward partials upstream, the root journals them"
            );
        }
        return cmd_edge_leader(cfg, &up, &addr, workers, report_json);
    }
    // leader evaluates nothing; it needs x0 of the right dimension (the
    // quadratic branch keeps its backend to report gradient descent)
    let adir = artifacts_dir(args.opt("artifacts").unwrap_or(""));
    let (x0, quad) = match pick_backend(args, &adir)? {
        BackendKind::Pjrt(engine) => (engine.init_params(cfg.seeds[0] as i32)?, None),
        BackendKind::Quadratic => {
            let b = QuadraticBackend::new(
                128, 64, 1.0, 0.3, 0.2, 0.02, cfg.fl.local_steps, cfg.seeds[0],
            );
            (b.init_params(0)?, Some(b))
        }
    };
    let d = x0.len();
    // captured before cfg moves into the leader: the report JSON names
    // the aggregation rule the per-worker robust counters ran under
    let robust_json = {
        use qafel::util::json::Json;
        Json::obj(vec![
            ("enabled", Json::Bool(cfg.fl.robust.enabled)),
            ("clip_norm", Json::num(cfg.fl.robust.clip_norm)),
            ("normalize", Json::Bool(cfg.fl.robust.normalize)),
            ("trim_frac", Json::num(cfg.fl.robust.trim_frac)),
        ])
    };
    println!("[leader] serving on {addr}, waiting for {workers} workers ...");
    let mut leader = Leader::new(cfg, x0.clone(), 1);
    leader.resume = resume;
    let report = leader.run(&addr, workers)?;
    println!("[leader] done: {} steps, {} uploads, kB/up {:.3}, staleness max {} mean {:.2}",
             report.server_steps, report.comm.uploads, report.comm.kb_per_upload(),
             report.staleness_max, report.staleness_mean);
    println!("[leader] fingerprint {}", report.fingerprint);
    print_stage_timings(&report.stage_timings);
    let grad_ratio = quad.map(|b| {
        let g0 = b.grad_norm_sq(&x0);
        let g1 = b.grad_norm_sq(&report.model);
        let ratio = if g0 > 0.0 { g1 / g0 } else { 0.0 };
        println!("[leader] |grad f|^2: {g0:.4} -> {g1:.4} (ratio {ratio:.4})");
        ratio
    });
    println!("[leader] worker    peer                  proto codec         rekeys uploads      kB-up  stale-mean  stale-max");
    for ws in &report.worker_stats {
        println!(
            "[leader] {:<9} {:<21} v{:<4} {:<13} {:>6} {:>7} {:>10.3} {:>11.2} {:>10}",
            ws.worker_id,
            ws.peer,
            ws.protocol,
            ws.codec,
            ws.rekeys,
            ws.uploads,
            ws.upload_bytes as f64 / 1000.0,
            ws.staleness.mean(),
            ws.staleness.max,
        );
    }
    if let Some(path) = report_json {
        use qafel::util::json::Json;
        let mut workers_json = Vec::new();
        for ws in &report.worker_stats {
            let expected = qafel::quant::parse_spec(&ws.codec)?.expected_bytes(d);
            let expected_down = qafel::quant::parse_spec(&ws.server_codec)?.expected_bytes(d);
            // per-codec-epoch accounting: the join codec first, then one
            // entry per mid-run Rekey (tools/check_net_e2e.py --adaptive)
            let mut epochs_json = Vec::new();
            for ep in &ws.epochs {
                let ep_expected = qafel::quant::parse_spec(&ep.codec)?.expected_bytes(d);
                epochs_json.push(Json::obj(vec![
                    ("codec_id", Json::num(ep.codec_id as f64)),
                    ("codec", Json::str(ep.codec.clone())),
                    ("uploads", Json::num(ep.uploads as f64)),
                    ("upload_bytes", Json::num(ep.upload_bytes as f64)),
                    ("expected_bytes_per_upload", Json::num(ep_expected as f64)),
                ]));
            }
            workers_json.push(Json::obj(vec![
                ("worker_id", Json::num(ws.worker_id as f64)),
                ("peer", Json::str(ws.peer.clone())),
                ("protocol", Json::num(ws.protocol as f64)),
                ("codec_id", Json::num(ws.codec_id as f64)),
                ("codec", Json::str(ws.codec.clone())),
                ("bandwidth_hint", ws.bandwidth_hint.map(|h| Json::num(h as f64)).unwrap_or(Json::Null)),
                ("rekeys", Json::num(ws.rekeys as f64)),
                ("epochs", Json::arr(epochs_json)),
                ("uploads", Json::num(ws.uploads as f64)),
                ("upload_bytes", Json::num(ws.upload_bytes as f64)),
                ("partials", Json::num(ws.partials as f64)),
                ("expected_bytes_per_upload", Json::num(expected as f64)),
                ("server_codec_id", Json::num(ws.server_codec_id as f64)),
                ("server_codec", Json::str(ws.server_codec.clone())),
                ("expected_bytes_per_download", Json::num(expected_down as f64)),
                ("broadcast_frames", Json::num(ws.broadcast_frames as f64)),
                ("broadcast_bytes", Json::num(ws.broadcast_bytes as f64)),
                ("skipped_broadcasts", Json::num(ws.skipped_broadcasts as f64)),
                ("catch_up_frames", Json::num(ws.catch_up_frames as f64)),
                ("full_syncs", Json::num(ws.full_syncs as f64)),
                ("staleness_mean", Json::num(ws.staleness.mean())),
                ("staleness_max", Json::num(ws.staleness.max as f64)),
                ("ingest_ns", Json::num(ws.ingest_ns as f64)),
                ("send_ns", Json::num(ws.send_ns as f64)),
                ("clipped_updates", Json::num(ws.clipped_updates as f64)),
                ("trimmed_updates", Json::num(ws.trimmed_updates as f64)),
            ]));
        }
        let doc = Json::obj(vec![
            ("d", Json::num(d as f64)),
            ("fingerprint", Json::str(report.fingerprint.clone())),
            ("stage_timings", report.stage_timings.to_json()),
            ("server_steps", Json::num(report.server_steps as f64)),
            ("uploads", Json::num(report.comm.uploads as f64)),
            ("upload_bytes", Json::num(report.comm.upload_bytes as f64)),
            ("broadcasts", Json::num(report.comm.broadcasts as f64)),
            ("broadcast_bytes", Json::num(report.comm.broadcast_bytes as f64)),
            ("staleness_max", Json::num(report.staleness_max as f64)),
            ("staleness_mean", Json::num(report.staleness_mean)),
            ("grad_ratio", grad_ratio.map(Json::num).unwrap_or(Json::Null)),
            ("robust", robust_json),
            ("workers", Json::arr(workers_json)),
        ]);
        std::fs::write(&path, doc.pretty())
            .map_err(|e| anyhow!("writing report {path}: {e}"))?;
        println!("[leader] report written to {path}");
    }
    Ok(())
}

/// Run as an interior tree node: join `upstream` as a v2 worker, serve
/// `workers` downstream connections on `addr`, forward partial
/// aggregates (see `net/edge.rs`).
fn cmd_edge_leader(
    cfg: Config,
    upstream: &str,
    addr: &str,
    workers: usize,
    report_json: Option<String>,
) -> Result<()> {
    use qafel::net::EdgeLeader;
    // distinct quantization noise per edge without extra flags: fold the
    // listen address into the seed (deterministic for a fixed topology)
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in addr.as_bytes() {
        h = (h ^ *b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    let seed = cfg.seeds[0] ^ h;
    println!("[edge] upstream {upstream}, serving on {addr}, waiting for {workers} workers ...");
    let report = EdgeLeader::new(cfg, seed).run(upstream, addr, workers)?;
    println!(
        "[edge {}] done: {} updates in, {} partials up ({} pending dropped), replica t={}, \
         codec {}",
        report.edge_worker_id,
        report.updates,
        report.partials,
        report.pending_at_shutdown,
        report.replica_t,
        report.partial_codec
    );
    if let Some(path) = report_json {
        use qafel::util::json::Json;
        let expected = qafel::quant::parse_spec(&report.partial_codec)?.expected_bytes(report.d);
        let mut workers_json = Vec::new();
        for ws in &report.worker_stats {
            workers_json.push(Json::obj(vec![
                ("worker_id", Json::num(ws.worker_id as f64)),
                ("peer", Json::str(ws.peer.clone())),
                ("protocol", Json::num(ws.protocol as f64)),
                ("codec_id", Json::num(ws.codec_id as f64)),
                ("codec", Json::str(ws.codec.clone())),
                ("uploads", Json::num(ws.uploads as f64)),
                ("upload_bytes", Json::num(ws.upload_bytes as f64)),
                ("broadcast_frames", Json::num(ws.broadcast_frames as f64)),
                ("broadcast_bytes", Json::num(ws.broadcast_bytes as f64)),
                ("staleness_mean", Json::num(ws.staleness.mean())),
                ("staleness_max", Json::num(ws.staleness.max as f64)),
            ]));
        }
        let doc = Json::obj(vec![
            ("edge_worker_id", Json::num(report.edge_worker_id as f64)),
            ("d", Json::num(report.d as f64)),
            ("updates", Json::num(report.updates as f64)),
            ("update_bytes", Json::num(report.update_bytes as f64)),
            ("partials", Json::num(report.partials as f64)),
            ("partial_bytes", Json::num(report.partial_bytes as f64)),
            ("expected_bytes_per_partial", Json::num(expected as f64)),
            ("pending_at_shutdown", Json::num(report.pending_at_shutdown as f64)),
            ("replica_t", Json::num(report.replica_t as f64)),
            ("partial_codec", Json::str(report.partial_codec.clone())),
            ("staleness_mean", Json::num(report.staleness.mean())),
            ("staleness_max", Json::num(report.staleness.max as f64)),
            ("workers", Json::arr(workers_json)),
        ]);
        std::fs::write(&path, doc.pretty())
            .map_err(|e| anyhow!("writing report {path}: {e}"))?;
        println!("[edge] report written to {path}");
    }
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let addr = args.opt("addr").unwrap_or(cfg.net.addr.as_str()).to_string();
    let delay_ms: u64 = args.opt_or("round-delay-ms", 5)?;
    let mut w = Worker::new(QuadraticBackend::new(
        128,
        64,
        1.0,
        0.3,
        0.2,
        0.02,
        cfg.fl.local_steps,
        cfg.seeds[0],
    ));
    w.round_delay = std::time::Duration::from_millis(delay_ms);
    w.shards = cfg.fl.shards;
    // per-worker codec negotiation (wire v2): explicit spec > tier name
    w.tier = args.opt("tier").map(str::to_string).or_else(|| cfg.net.tier.clone());
    w.quant_client =
        args.opt("quant-client").map(str::to_string).or_else(|| cfg.net.quant_client.clone());
    // advertised uplink bandwidth (Mbit/s) for the leader's adaptive
    // controller; v1 peers never send it (net.adaptive, ARCHITECTURE.md)
    w.bandwidth_hint = args.opt_parse::<f32>("bandwidth-mbps")?;
    w.force_v1 = args.flag("v1");
    // robustness drills: corrupt every upload before quantization
    // (sign_flip | scale:<c> | stale_replay; bad specs fail fast in run)
    w.adversary = args.opt("adversary").map(str::to_string);
    let timings = args.flag("timings");
    if timings {
        qafel::telemetry::set_enabled(true);
    }
    let report = w.run(&addr)?;
    let adv = if report.adversary.is_empty() {
        String::new()
    } else {
        format!(", adversary {}", report.adversary)
    };
    println!(
        "[worker {}] {} uploads, replica t={}, protocol v{}, codec {}{adv}",
        report.worker_id, report.uploads, report.replica_t, report.protocol, report.codec
    );
    if timings && report.uploads > 0 {
        let per = |ns: u64| ns as f64 / report.uploads as f64 / 1000.0;
        println!(
            "[worker {}] us/round: train {:.1}, encode {:.1}, send {:.1} \
             (broadcast decode total {:.1} us)",
            report.worker_id,
            per(report.train_ns),
            per(report.encode_ns),
            per(report.send_ns),
            report.decode_ns as f64 / 1000.0,
        );
    }
    Ok(())
}

/// `qafel journal tail|replay <file.jsonl>` — inspect or verify a
/// flight-recorder journal (ARCHITECTURE.md §Telemetry).
fn cmd_journal(args: &Args) -> Result<()> {
    use qafel::scenario::StalenessHist;
    use qafel::telemetry::{progress_line, replay_file, Event, JournalReader};
    let verb = args.positional.get(1).map(|s| s.as_str());
    let path = match (verb, args.positional.get(2)) {
        (Some("tail" | "replay"), None) => {
            bail!("journal {} needs a journal path", verb.unwrap_or(""))
        }
        (_, p) => p.map(|s| s.as_str()).unwrap_or(""),
    };
    match verb {
        Some("tail") => {
            let events = JournalReader::read(path)?;
            let mut hist = StalenessHist::default();
            let mut prev_step: Option<Event> = None;
            for ev in &events {
                match ev {
                    Event::Meta { runtime, algorithm, d, seed, fingerprint, git, .. } => {
                        println!(
                            "meta       {runtime}/{algorithm} d={d} seed={seed} \
                             fingerprint={fingerprint} git={}",
                            git.as_deref().unwrap_or("-")
                        );
                    }
                    Event::Codec { reg, id, spec } => {
                        println!("codec      {reg}[{id}] = {spec}");
                    }
                    Event::Init { x0, server_seed } => {
                        println!("init       x0[{}] server_seed={server_seed}", x0.len());
                    }
                    Event::Arrival { time, tier, user, trip, t_start, dropped, partial } => {
                        let fate = match (dropped, partial) {
                            (true, Some(p)) => format!(" DROPPED(partial {p:.2})"),
                            (true, None) => " DROPPED".to_string(),
                            _ => String::new(),
                        };
                        println!(
                            "arrival    t={time:.3} {tier} user={user} trip={trip} \
                             from-step={t_start}{fate}"
                        );
                    }
                    Event::Ingest { time, step, worker, codec, staleness, payload } => {
                        println!(
                            "ingest     t={time:.3} step={step} worker={worker} \
                             codec={codec} staleness={staleness} {}B",
                            payload.len()
                        );
                        hist.record(*staleness);
                    }
                    Event::IngestPartial {
                        time,
                        step,
                        worker,
                        codec,
                        count,
                        stale_counts,
                        stale_sum,
                        stale_max,
                        stale_n,
                        payload,
                    } => {
                        println!(
                            "partial    t={time:.3} step={step} edge={worker} \
                             codec={codec} count={count} {}B",
                            payload.len()
                        );
                        hist.merge(&StalenessHist::from_parts(
                            stale_counts.clone(),
                            *stale_sum,
                            *stale_max,
                            *stale_n,
                        ));
                    }
                    Event::Step { .. } => {
                        if let Some(line) = progress_line(ev, prev_step.as_ref(), &hist) {
                            println!("{line}");
                        }
                        prev_step = Some(ev.clone());
                    }
                    Event::Broadcast { time, step, absolute, codec, payload } => {
                        println!(
                            "broadcast  t={time:.3} step={step} family={codec} {}B{}",
                            payload.len(),
                            if *absolute { " (absolute)" } else { "" }
                        );
                    }
                    Event::Eval { time, step, uploads, val_loss, val_accuracy } => {
                        println!(
                            "eval       t={time:.3} step={step} uploads={uploads} \
                             loss={val_loss:.4} acc={val_accuracy:.4}"
                        );
                    }
                    Event::Checkpoint { time, step, .. } => {
                        println!("checkpoint t={time:.3} step={step}");
                    }
                    Event::Final {
                        step,
                        uploads,
                        upload_bytes,
                        broadcasts,
                        broadcast_bytes,
                        model,
                    } => {
                        println!(
                            "final      step={step} uploads={uploads} \
                             ({upload_bytes}B up) broadcasts={broadcasts} \
                             ({broadcast_bytes}B down) model[{}]",
                            model.len()
                        );
                    }
                }
            }
            println!("-- {} events", events.len());
            Ok(())
        }
        Some("replay") => {
            let report = replay_file(path)?;
            println!(
                "replay OK: {} steps, {} ingests, {} broadcasts verified \
                 bit-for-bit, {} checkpoints{}",
                report.steps,
                report.uploads,
                report.broadcasts_checked,
                report.checkpoints,
                if report.finalized {
                    ", final model verified"
                } else {
                    " (no Final event — journal from a killed run)"
                }
            );
            Ok(())
        }
        other => bail!(
            "journal needs tail|replay (got {:?}); \
             usage: qafel journal <tail|replay> <file.jsonl>",
            other
        ),
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let adir = artifacts_dir(args.opt("artifacts").unwrap_or(""));
    let m = qafel::runtime::Manifest::load(&adir)?;
    println!("artifacts: {adir}");
    println!("model: d={} ({}x{}x{} input, {} conv layers, {} channels)",
             m.model.d, m.model.height, m.model.width, m.model.in_channels,
             m.model.n_layers, m.model.channels);
    println!("train: batch={} local_steps={} eval_batch={}", m.batch, m.local_steps, m.eval_batch);
    for (name, a) in &m.artifacts {
        println!("  {name:<28} {} in / {} out   ({})", a.inputs.len(), a.outputs.len(), a.file);
    }
    println!("full-precision update: {:.3} kB (paper: 117.128 kB at d=29,282)",
             m.model.d as f64 * 4.0 / 1000.0);
    Ok(())
}

fn cmd_selfcheck(args: &Args) -> Result<()> {
    use qafel::quant::qsgd::Qsgd;
    use qafel::util::prng::Prng;
    let adir = artifacts_dir(args.opt("artifacts").unwrap_or(""));
    let engine = Engine::load(&adir)?;
    let d = engine.d();
    println!("[1/3] artifacts compiled (d = {d})");

    // rust qsgd levels == Pallas kernel levels for identical noise
    let mut rng = Prng::new(42);
    let x: Vec<f32> = (0..d).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let mut u = vec![0.0f32; d];
    rng.fill_uniform_f32(&mut u);
    let q = Qsgd::new(4)?;
    let s = q.levels() as f32;
    let g = q.bucket();
    let (levels_pallas, norms_pallas) = engine.qsgd_quantize(&x, &u, s)?;
    // replicate in rust with the same uniforms (per-bucket norms)
    let mut mismatches = 0usize;
    for i in 0..d {
        let b = i / g;
        let lo = b * g;
        let hi = (lo + g).min(d);
        let norm = qafel::util::vecf::norm2(&x[lo..hi]) as f32;
        let a = x[i].abs() * s / norm;
        let lv = (a + u[i]).floor() as i32;
        let expect = if x[i] < 0.0 { -lv } else { lv };
        if levels_pallas[i] != expect {
            mismatches += 1;
        }
    }
    if mismatches > d / 10_000 + 1 {
        bail!("qsgd mismatch: {mismatches} of {d} levels differ");
    }
    println!("[2/3] Pallas qsgd kernel == rust codec ({mismatches} level mismatches of {d})");

    // codec round trip through the wire format
    let msg = q.encode_levels(&levels_pallas, &norms_pallas);
    let (n2, lv2) = q.decode_levels(&msg)?;
    if n2 != norms_pallas || lv2 != levels_pallas {
        bail!("wire codec round-trip failed");
    }
    println!("[3/3] wire codec round-trip exact ({} bytes for d={d}, {:.2} bits/coord)",
             msg.wire_bytes(), msg.wire_bytes() as f64 * 8.0 / d as f64);

    // end-to-end: one client_update call descends
    let params = engine.init_params(0)?;
    let m = engine.manifest();
    let (p, b) = (m.local_steps, m.batch);
    let img = engine.img_elems();
    let cfgd = qafel::config::DataConfig::default();
    let ds = qafel::data::Dataset::new(&cfgd);
    let mut xs = vec![0.0f32; p * b * img];
    let mut ys = vec![0i32; p * b];
    let mut mask = vec![0.0f32; p * b];
    let mut brng = Prng::new(7);
    ds.fill_round(0, &mut brng, p, b, &mut xs, &mut ys, &mut mask);
    let out = engine.client_update(&params, &xs, &ys, &mask, 0.01, 1)?;
    println!("client_update: |delta| = {:.4}, loss = {:.4}, acc = {:.3}",
             qafel::util::vecf::norm2(&out.delta), out.loss, out.acc);
    println!("selfcheck OK");
    Ok(())
}

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand() {
        Some("exp") => cmd_exp(&args),
        Some("run") => cmd_run(&args),
        Some("scenario") => cmd_scenario(&args),
        Some("leader") => cmd_leader(&args),
        Some("worker") => cmd_worker(&args),
        Some("journal") => cmd_journal(&args),
        Some("info") => cmd_info(&args),
        Some("selfcheck") => cmd_selfcheck(&args),
        Some("version") => {
            println!("qafel {}", qafel::version());
            Ok(())
        }
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
