//! Composable buffered aggregation — the tree-of-leaders seam.
//!
//! QAFeL inherits FedBuff's single-server buffered aggregation, so one
//! node ingesting every upload is the scalability wall. But buffered
//! aggregation *composes*: the count-weighted buffer an aggregator
//! accumulates is mathematically just another client update, so an
//! **edge aggregator** can ingest a slice of the population, quantize
//! its partial buffer with a partial codec `Q_p`, and forward it
//! upstream exactly like an upload. The [`Aggregator`] trait captures
//! the seam:
//!
//! ```text
//!            clients ──Q_c──▶ EdgeAggregator ──Q_p──▶ ┐
//!            clients ──Q_c──▶ EdgeAggregator ──Q_p──▶ ├─▶ Server (root)
//!            clients ──Q_c──▶ EdgeAggregator ──Q_p──▶ ┘      │
//!                                                        Q_s broadcast
//! ```
//!
//! * An **edge** ([`EdgeAggregator`]) ingests updates through the same
//!   codec-registry path as the server ([`Server::ingest_from`]'s loud
//!   size/dimension validation), applies the staleness weight `w(τ)`
//!   locally, and on buffer-full emits a [`PartialAggregate`]:
//!   `Q_p(Δ̄_edge)` + the update count + the staleness histogram.
//! * The **root** ([`Server`]) ingests partials with
//!   [`Server::ingest_partial`]: decode with the registered partial
//!   codec, accumulate with weight 1 (staleness weights were applied at
//!   the edge), advance the buffer fill by `count`, and step as usual
//!   (momentum, η_g, `Q_s` encode, x̂ advance) when `K` fills.
//! * Edges also accept partials from deeper edges
//!   ([`Aggregator::ingest_partial_aggregate`]), so trees of any depth
//!   compose from the same two node types.
//!
//! **Bit-identity contract** (the repo's signature invariant): a
//! trivial tree — one edge, `buffer_size = 1` (forward every update),
//! identity partial codec — replays **bit-identical** to the flat
//! server. This holds because (a) the identity codec is an exact f32
//! passthrough that draws no quantizer randomness, so the edge's PRNG
//! stream never perturbs anything; (b) the edge buffer starts at +0.0
//! and IEEE-754 round-to-nearest guarantees `0.0 + w·v` has the same
//! bits as `w·v` except `-0.0 ↦ +0.0`, and adding `+0.0` vs `-0.0` to
//! a buffer that can itself never hold `-0.0` is bitwise identical;
//! (c) the root accumulates partials with weight exactly 1.0
//! (`fl(1.0 · v) = v`). The golden tests in this module and
//! `rust/tests/aggregator_tree.rs` pin the contract.

use crate::config::{Algorithm, Config, RobustConfig};
use crate::coordinator::server::{client_codec_spec, Broadcast, Server, ServerStep};
use crate::quant::{parse_spec, sharded, QuantizedMsg, Quantizer};
use crate::scenario::metrics::StalenessHist;
use crate::util::pool::ShardPool;
use crate::util::prng::Prng;
use crate::util::vecf;
use anyhow::{bail, Result};
use std::sync::Arc;

/// A quantized partial aggregate, forwarded upstream like an upload.
#[derive(Clone, Debug)]
pub struct PartialAggregate {
    /// `Q_p(Δ̄_edge)` — the edge's count-weighted buffer, encoded with
    /// the partial codec.
    pub msg: QuantizedMsg,
    /// Client updates folded into `msg`; the upstream aggregator
    /// advances its buffer fill by this many slots.
    pub count: u32,
    /// Staleness of the folded updates. Weights `w(τ)` were already
    /// applied downstream — the histogram travels for accounting only
    /// and is merged up the tree.
    pub staleness: StalenessHist,
}

impl PartialAggregate {
    pub fn wire_bytes(&self) -> usize {
        self.msg.wire_bytes()
    }
}

/// Outcome of one ingest at any tree node.
#[derive(Clone, Debug)]
pub enum AggOutcome {
    /// Buffered; this node's buffer is not yet full.
    Buffered,
    /// Root only: buffer filled, server step taken, one broadcast per
    /// downlink family emitted (family 0 first).
    Stepped(Vec<Broadcast>),
    /// Edge only: buffer filled, partial aggregate ready to forward.
    Forward(PartialAggregate),
}

/// A node in the aggregation tree: ingests client updates (and partial
/// aggregates from deeper nodes) and either applies the buffer (root)
/// or forwards it upstream (edge).
pub trait Aggregator {
    /// Model dimension d.
    fn d(&self) -> usize;

    /// Ingest one quantized client update, decoded with the registered
    /// codec `codec` (same registry semantics as
    /// [`Server::ingest_from`]: registration order is the wire
    /// contract, mismatches fail loudly).
    fn ingest_update(
        &mut self,
        update: &QuantizedMsg,
        staleness: u64,
        codec: usize,
    ) -> Result<AggOutcome>;

    /// Ingest a partial aggregate forwarded by a downstream aggregator,
    /// decoded with the registered partial codec `codec`.
    fn ingest_partial_aggregate(
        &mut self,
        partial: &PartialAggregate,
        codec: usize,
    ) -> Result<AggOutcome>;
}

impl Aggregator for Server {
    fn d(&self) -> usize {
        Server::d(self)
    }

    fn ingest_update(
        &mut self,
        update: &QuantizedMsg,
        staleness: u64,
        codec: usize,
    ) -> Result<AggOutcome> {
        Ok(match self.ingest_from(update, staleness, codec)? {
            ServerStep::Buffered => AggOutcome::Buffered,
            ServerStep::Stepped(b) => AggOutcome::Stepped(b),
        })
    }

    fn ingest_partial_aggregate(
        &mut self,
        partial: &PartialAggregate,
        codec: usize,
    ) -> Result<AggOutcome> {
        Ok(
            match self.ingest_partial(&partial.msg, partial.count, &partial.staleness, codec)? {
                ServerStep::Buffered => AggOutcome::Buffered,
                ServerStep::Stepped(b) => AggOutcome::Stepped(b),
            },
        )
    }
}

/// An edge aggregator: the server's ingest half (codec registry, loud
/// validation, staleness weighting, shard-parallel accumulate) without
/// the model half (no x, no momentum, no broadcast). On buffer-full it
/// encodes the buffer with the partial codec and hands the caller a
/// [`PartialAggregate`] to forward upstream.
pub struct EdgeAggregator {
    d: usize,
    /// Edge buffer size B (1 = forward every update immediately).
    buffer_size: usize,
    algorithm: Algorithm,
    staleness_scaling: bool,
    /// Codecs for decoding client uploads; same registry semantics as
    /// [`Server::register_client_codec`].
    client_codecs: Vec<Box<dyn Quantizer>>,
    /// `Q_p`: encodes the forwarded partial buffer.
    partial_codec: Box<dyn Quantizer>,
    /// Robust knobs ([`EdgeAggregator::with_robust`]). Edges apply the
    /// per-update norm clip at *their* ingest point — the partial then
    /// travels upstream pre-clipped, so clipping commutes with
    /// count-weighted forwarding exactly like the staleness weight
    /// does. Trimming never runs at an edge (config validation rejects
    /// trim+edges: a partial has already collapsed its rows).
    robust: RobustConfig,
    /// Scratch for one decoded update when clipping is on (empty
    /// otherwise).
    robust_scratch: Vec<f32>,
    pool: Arc<ShardPool>,
    /// Randomness for `Q_p` (drawn only by stochastic partial codecs;
    /// the identity codec consumes nothing — load-bearing for the
    /// trivial-tree bit-identity contract).
    rng: Prng,
    // --- state -------------------------------------------------------------
    /// Count-weighted partial buffer Δ̄_edge.
    buffer: Vec<f32>,
    k_filled: usize,
    /// Staleness of the updates in the *current* buffer (shipped with
    /// the next partial).
    hist: StalenessHist,
    // --- accounting --------------------------------------------------------
    /// Client updates ingested (direct + folded via child partials).
    pub updates: u64,
    /// Wire bytes of ingested uploads/partials.
    pub update_bytes: u64,
    /// Partial aggregates emitted upstream.
    pub forwarded: u64,
    /// Wire bytes of emitted partials.
    pub forwarded_bytes: u64,
    /// Lifetime staleness histogram over everything ingested here.
    pub staleness: StalenessHist,
    /// Updates shrunk by the norm clip at this edge.
    pub clipped_updates: u64,
}

impl EdgeAggregator {
    /// Build an edge for model dimension `d`. `client_spec` becomes
    /// codec id 0 (resolved per algorithm exactly like the server's
    /// default); `partial_spec` is parsed raw — partials carry
    /// already-decoded buffer values, not client deltas.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        d: usize,
        buffer_size: usize,
        partial_spec: &str,
        client_spec: &str,
        algorithm: Algorithm,
        staleness_scaling: bool,
        pool: Arc<ShardPool>,
        seed: u64,
    ) -> Result<EdgeAggregator> {
        if buffer_size == 0 {
            bail!("edge aggregator: buffer_size must be >= 1");
        }
        let quant_c = parse_spec(&client_codec_spec(client_spec, algorithm))?;
        let partial_codec = parse_spec(partial_spec)?;
        Ok(EdgeAggregator {
            d,
            buffer_size,
            algorithm,
            staleness_scaling,
            client_codecs: vec![quant_c],
            partial_codec,
            pool,
            rng: Prng::new(seed).stream("edge-quant"),
            buffer: vec![0.0; d],
            k_filled: 0,
            hist: StalenessHist::default(),
            robust: RobustConfig::default(),
            robust_scratch: Vec::new(),
            updates: 0,
            update_bytes: 0,
            forwarded: 0,
            forwarded_bytes: 0,
            staleness: StalenessHist::default(),
            clipped_updates: 0,
        })
    }

    /// Enable robust ingest at this edge (builder). Only the clip knobs
    /// apply here — trimming is a root-only stage, and config
    /// validation rejects trim with edge trees before a tree is built.
    pub fn with_robust(mut self, robust: &RobustConfig) -> EdgeAggregator {
        self.robust = robust.clone();
        self.robust_scratch =
            if self.robust.clip_enabled() { vec![0.0; self.d] } else { Vec::new() };
        self
    }

    pub fn buffer_size(&self) -> usize {
        self.buffer_size
    }

    /// Updates currently sitting in the (not yet forwarded) buffer.
    pub fn pending(&self) -> usize {
        self.k_filled
    }

    /// Spec name of the partial codec `Q_p`.
    pub fn partial_codec_name(&self) -> String {
        self.partial_codec.name()
    }

    /// Wire bytes of one emitted partial at this edge's dimension.
    pub fn partial_bytes(&self) -> usize {
        self.partial_codec.expected_bytes(self.d)
    }

    /// Register an extra client-upload codec; identical registry
    /// semantics to [`Server::register_client_codec`] (per-algorithm
    /// resolution, dedup by resolved name, order = wire contract).
    pub fn register_client_codec(&mut self, spec: &str) -> Result<usize> {
        let resolved = client_codec_spec(spec, self.algorithm);
        let codec = parse_spec(&resolved)?;
        if let Some(i) = self.client_codecs.iter().position(|c| c.name() == codec.name()) {
            return Ok(i);
        }
        self.client_codecs.push(codec);
        Ok(self.client_codecs.len() - 1)
    }

    /// Register every tier's `quant_client` preset in tier order — the
    /// same ids [`Server::register_tier_presets`] assigns, so every
    /// node of the tree agrees on the codec registry for one config.
    pub fn register_tier_presets(&mut self, cfg: &Config) -> Result<Vec<usize>> {
        cfg.resolved_tiers()
            .iter()
            .map(|t| match &t.quant_client {
                Some(spec) => self.register_client_codec(spec),
                None => Ok(0),
            })
            .collect()
    }

    pub fn num_client_codecs(&self) -> usize {
        self.client_codecs.len()
    }

    pub fn client_codec_name(&self, codec: usize) -> String {
        self.client_codecs[codec].name()
    }

    /// Ingest one client update with the default codec (id 0).
    pub fn ingest(&mut self, update: &QuantizedMsg, staleness: u64) -> Result<AggOutcome> {
        self.ingest_from(update, staleness, 0)
    }

    /// Ingest one client update encoded with registered codec `codec` —
    /// the same heterogeneous path as [`Server::ingest_from`], with the
    /// same loud validation order (nothing is recorded for a rejected
    /// upload).
    pub fn ingest_from(
        &mut self,
        update: &QuantizedMsg,
        staleness: u64,
        codec: usize,
    ) -> Result<AggOutcome> {
        let quant_c = self
            .client_codecs
            .get(codec)
            .ok_or_else(|| anyhow::anyhow!("edge: unknown client codec id {codec}"))?;
        if update.d != self.d {
            bail!("edge: upload dimension {} != model dimension {}", update.d, self.d);
        }
        let expect = quant_c.expected_bytes(self.d);
        if update.wire_bytes() != expect {
            bail!(
                "edge: upload payload is {} bytes but client codec '{}' expects {} \
                 at d={} — client and edge quantizer specs disagree",
                update.wire_bytes(),
                quant_c.name(),
                expect,
                self.d
            );
        }
        self.updates += 1;
        self.update_bytes += update.wire_bytes() as u64;
        self.hist.record(staleness);
        self.staleness.record(staleness);

        // w(τ) is applied here, at the ingest point — partials travel
        // upstream pre-weighted, exactly as the flat server would have
        // weighted each update.
        let w = if self.staleness_scaling {
            1.0 / ((1.0 + staleness as f64).sqrt() as f32)
        } else {
            1.0
        };
        let quant_c = self.client_codecs[codec].as_ref();
        if self.robust.clip_enabled() {
            // Same robust path as [`Server::ingest_from`]: decode to
            // scratch, bound the norm, fold the scale into the weight.
            sharded::dequantize_into(quant_c, update, &mut self.robust_scratch, &self.pool)?;
            let norm = vecf::norm2(&self.robust_scratch);
            let clip = self.robust.clip_norm;
            let mut w_eff = w;
            if norm > clip {
                self.clipped_updates += 1;
            }
            if norm > 0.0 && (self.robust.normalize || norm > clip) {
                w_eff *= (clip / norm) as f32;
            }
            sharded::accumulate(quant_c, update, w_eff, &mut self.buffer, &self.pool)?;
        } else {
            sharded::accumulate(quant_c, update, w, &mut self.buffer, &self.pool)?;
        }
        self.k_filled += 1;

        if self.k_filled < self.buffer_size {
            return Ok(AggOutcome::Buffered);
        }
        self.flush().map(AggOutcome::Forward)
    }

    /// Encode and emit the current buffer as a partial aggregate,
    /// resetting the buffer. Called automatically on buffer-full; also
    /// callable directly to drain a partially filled buffer (e.g. at
    /// shutdown). Fails on an empty buffer.
    pub fn flush(&mut self) -> Result<PartialAggregate> {
        if self.k_filled == 0 {
            bail!("edge: flush of an empty buffer");
        }
        let msg =
            sharded::quantize(self.partial_codec.as_ref(), &self.buffer, &mut self.rng, &self.pool);
        vecf::zero(&mut self.buffer);
        let count = self.k_filled as u32;
        self.k_filled = 0;
        let staleness = std::mem::take(&mut self.hist);
        self.forwarded += 1;
        self.forwarded_bytes += msg.wire_bytes() as u64;
        Ok(PartialAggregate { msg, count, staleness })
    }
}

impl Aggregator for EdgeAggregator {
    fn d(&self) -> usize {
        self.d
    }

    fn ingest_update(
        &mut self,
        update: &QuantizedMsg,
        staleness: u64,
        codec: usize,
    ) -> Result<AggOutcome> {
        self.ingest_from(update, staleness, codec)
    }

    /// Fold a child edge's partial into this edge's buffer (deeper
    /// trees). Edges keep a single partial codec used for both decode
    /// (from children) and encode (upstream), so `codec` must be 0 —
    /// a uniform-`Q_p` tree.
    fn ingest_partial_aggregate(
        &mut self,
        partial: &PartialAggregate,
        codec: usize,
    ) -> Result<AggOutcome> {
        if codec != 0 {
            bail!("edge: unknown partial codec id {codec} (edges hold a single Q_p)");
        }
        if partial.msg.d != self.d {
            bail!(
                "edge: partial dimension {} != model dimension {}",
                partial.msg.d,
                self.d
            );
        }
        let expect = self.partial_codec.expected_bytes(self.d);
        if partial.msg.wire_bytes() != expect {
            bail!(
                "edge: partial payload is {} bytes but partial codec '{}' expects {} \
                 at d={}",
                partial.msg.wire_bytes(),
                self.partial_codec.name(),
                expect,
                self.d
            );
        }
        if partial.count == 0 {
            bail!("edge: partial aggregate with count 0");
        }
        self.updates += partial.count as u64;
        self.update_bytes += partial.msg.wire_bytes() as u64;
        self.hist.merge(&partial.staleness);
        self.staleness.merge(&partial.staleness);
        // weights were applied at the leaf edge: accumulate verbatim
        sharded::accumulate(
            self.partial_codec.as_ref(),
            &partial.msg,
            1.0,
            &mut self.buffer,
            &self.pool,
        )?;
        self.k_filled += partial.count as usize;
        if self.k_filled < self.buffer_size {
            return Ok(AggOutcome::Buffered);
        }
        self.flush().map(AggOutcome::Forward)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(algorithm: &str, k: usize) -> Config {
        let mut c = Config::default();
        c.fl.algorithm = Algorithm::parse(algorithm).unwrap();
        c.fl.buffer_size = k;
        c.fl.server_lr = 1.0;
        c.fl.server_momentum = 0.0;
        c
    }

    fn identity_msg(x: &[f32]) -> QuantizedMsg {
        let mut rng = Prng::new(0);
        parse_spec("none").unwrap().quantize(x, &mut rng)
    }

    #[test]
    fn edge_buffers_then_forwards_count_weighted_partial() {
        let pool = ShardPool::sequential();
        let mut e = EdgeAggregator::new(
            4, 3, "none", "none", Algorithm::FedBuff, false, pool, 1,
        )
        .unwrap();
        assert!(matches!(e.ingest(&identity_msg(&[3.0, 0.0, 0.0, 0.0]), 0).unwrap(), AggOutcome::Buffered));
        assert!(matches!(e.ingest(&identity_msg(&[0.0, 3.0, 0.0, 0.0]), 2).unwrap(), AggOutcome::Buffered));
        assert_eq!(e.pending(), 2);
        let p = match e.ingest(&identity_msg(&[0.0, 0.0, 3.0, 0.0]), 0).unwrap() {
            AggOutcome::Forward(p) => p,
            other => panic!("expected Forward, got {other:?}"),
        };
        // the partial is the raw (pre-division) buffer: the sum
        let decoded = parse_spec("none").unwrap().dequantize(&p.msg).unwrap();
        assert_eq!(decoded, vec![3.0, 3.0, 3.0, 0.0]);
        assert_eq!(p.count, 3);
        assert_eq!(p.staleness.n, 3);
        assert_eq!(p.staleness.max, 2);
        assert_eq!(p.staleness.sum, 2);
        // buffer reset; accounting reflects the emitted partial
        assert_eq!(e.pending(), 0);
        assert_eq!(e.updates, 3);
        assert_eq!(e.forwarded, 1);
        assert_eq!(e.forwarded_bytes, p.wire_bytes() as u64);
        assert_eq!(e.staleness.n, 3, "lifetime hist survives the flush");
    }

    #[test]
    fn edge_applies_staleness_weight_locally() {
        let pool = ShardPool::sequential();
        let mut e = EdgeAggregator::new(
            1, 1, "none", "none", Algorithm::FedBuff, true, pool, 1,
        )
        .unwrap();
        let p = match e.ingest(&identity_msg(&[1.0]), 3).unwrap() {
            AggOutcome::Forward(p) => p,
            other => panic!("expected Forward, got {other:?}"),
        };
        // w = 1/sqrt(1+3) = 0.5, applied at the edge, not upstream
        let decoded = parse_spec("none").unwrap().dequantize(&p.msg).unwrap();
        assert_eq!(decoded, vec![0.5]);
    }

    #[test]
    fn root_ingests_partial_and_steps() {
        let cfg = cfg("fedbuff", 3);
        let mut root = Server::build(&cfg, vec![0.0; 4], 1).unwrap();
        let pc = root.register_partial_codec("none").unwrap();
        assert_eq!(pc, 0);
        let pool = ShardPool::sequential();
        let mut e = EdgeAggregator::new(
            4, 3, "none", "none", Algorithm::FedBuff, false, pool, 1,
        )
        .unwrap();
        for v in [[3.0, 0.0, 0.0, 0.0], [0.0, 3.0, 0.0, 0.0]] {
            assert!(matches!(e.ingest(&identity_msg(&v), 0).unwrap(), AggOutcome::Buffered));
        }
        let p = match e.ingest(&identity_msg(&[0.0, 0.0, 3.0, 0.0]), 0).unwrap() {
            AggOutcome::Forward(p) => p,
            other => panic!("expected Forward, got {other:?}"),
        };
        // one partial carries K=3 updates: the root steps immediately
        match root.ingest_partial(&p.msg, p.count, &p.staleness, pc).unwrap() {
            ServerStep::Stepped(_) => {}
            other => panic!("expected step, got {other:?}"),
        }
        // x += eta_g * (sum / K) — identical to three flat ingests
        assert_eq!(root.model(), &[1.0, 1.0, 1.0, 0.0]);
        assert_eq!(root.t(), 1);
        // staleness accounting merged from the histogram (3 values, 1 upload)
        assert_eq!(root.staleness_n, 3);
        assert_eq!(root.comm.uploads, 1);
    }

    #[test]
    fn two_level_edges_compose_count_weighted() {
        let pool = ShardPool::sequential();
        let mut leaf = EdgeAggregator::new(
            2, 2, "none", "none", Algorithm::FedBuff, false, pool.clone(), 1,
        )
        .unwrap();
        let mut mid = EdgeAggregator::new(
            2, 4, "none", "none", Algorithm::FedBuff, false, pool, 2,
        )
        .unwrap();
        // two leaf partials of 2 updates each fill the mid buffer of 4
        for round in 0..2 {
            leaf.ingest(&identity_msg(&[1.0, 0.0]), round).unwrap();
            let p = match leaf.ingest(&identity_msg(&[0.0, 1.0]), round).unwrap() {
                AggOutcome::Forward(p) => p,
                other => panic!("expected Forward, got {other:?}"),
            };
            let out = mid.ingest_partial_aggregate(&p, 0).unwrap();
            if round == 0 {
                assert!(matches!(out, AggOutcome::Buffered));
                assert_eq!(mid.pending(), 2);
            } else {
                let p2 = match out {
                    AggOutcome::Forward(p2) => p2,
                    other => panic!("expected Forward, got {other:?}"),
                };
                assert_eq!(p2.count, 4);
                assert_eq!(p2.staleness.n, 4);
                let decoded = parse_spec("none").unwrap().dequantize(&p2.msg).unwrap();
                assert_eq!(decoded, vec![2.0, 2.0]);
            }
        }
        assert_eq!(mid.updates, 4);
    }

    #[test]
    fn edge_rejects_mismatches_loudly() {
        let pool = ShardPool::sequential();
        let mut e = EdgeAggregator::new(
            8, 2, "none", "qsgd:4", Algorithm::Qafel, false, pool, 1,
        )
        .unwrap();
        let mut rng = Prng::new(5);
        // wrong wire size for the negotiated codec
        let full = parse_spec("none").unwrap().quantize(&vec![1.0; 8], &mut rng);
        let err = e.ingest(&full, 0).unwrap_err().to_string();
        assert!(err.contains("qsgd:4"), "unhelpful error: {err}");
        // wrong dimension
        let qc = parse_spec("qsgd:4").unwrap();
        let short = qc.quantize(&vec![1.0; 4], &mut rng);
        assert!(e.ingest(&short, 0).is_err());
        // unknown codec id
        let ok = qc.quantize(&vec![1.0; 8], &mut rng);
        assert!(e.ingest_from(&ok, 0, 9).is_err());
        // nothing was recorded for the rejected uploads
        assert_eq!(e.updates, 0);
        assert_eq!(e.update_bytes, 0);
        // empty flush is an error, not a zero-count partial
        assert!(e.flush().is_err());
        // wrong-size partial from a child is rejected too
        let bad = PartialAggregate {
            msg: qc.quantize(&vec![1.0; 8], &mut rng),
            count: 1,
            staleness: StalenessHist::default(),
        };
        assert!(e.ingest_partial_aggregate(&bad, 0).is_err());
    }

    #[test]
    fn edge_registers_tier_presets_like_the_server() {
        let mut cfg = cfg("qafel", 2);
        cfg.quant.client = "none".into();
        cfg.scenario.tiers = vec![
            crate::config::TierConfig::named("fast"),
            {
                let mut t = crate::config::TierConfig::named("slow");
                t.quant_client = Some("top:0.25".into());
                t
            },
        ];
        let mut server = Server::new(&cfg, vec![0.0; 16], 1).unwrap();
        let pool = ShardPool::sequential();
        let mut edge = EdgeAggregator::new(
            16, 1, "none", &cfg.quant.client, cfg.fl.algorithm,
            cfg.fl.staleness_scaling, pool, 1,
        )
        .unwrap();
        let sids = server.register_tier_presets(&cfg).unwrap();
        let eids = edge.register_tier_presets(&cfg).unwrap();
        assert_eq!(sids, eids, "tree nodes must agree on the codec registry");
        assert_eq!(edge.num_client_codecs(), server.num_client_codecs());
        for i in 0..edge.num_client_codecs() {
            assert_eq!(edge.client_codec_name(i), server.client_codec_name(i));
        }
    }

    #[test]
    fn trivial_tree_replays_bit_identical_to_flat_server() {
        // The signature invariant: 1 edge, forward-every-update,
        // identity partial codec == today's flat server, bit for bit,
        // at every shard count.
        let mut base = cfg("qafel", 2);
        base.quant.client = "qsgd:8".into();
        base.quant.server = "qsgd:4".into();
        base.fl.server_momentum = 0.3;
        base.fl.staleness_scaling = true;
        let d = 2 * 128 + 19; // ragged tail
        for shards in [1usize, 4] {
            let mut cfg = base.clone();
            cfg.fl.shards = shards;
            let mut flat = Server::build(&cfg, vec![0.0; d], 7).unwrap();
            let mut root = Server::build(&cfg, vec![0.0; d], 7).unwrap();
            let pc = root.register_partial_codec("none").unwrap();
            let mut edge = EdgeAggregator::new(
                d, 1, "none", &cfg.quant.client, cfg.fl.algorithm,
                cfg.fl.staleness_scaling, ShardPool::new(shards), 99,
            )
            .unwrap();
            let qc = parse_spec("qsgd:8").unwrap();
            let mut rng_a = Prng::new(11);
            let mut rng_b = Prng::new(11);
            for round in 0..12u64 {
                let delta: Vec<f32> =
                    (0..d).map(|i| ((i as f32) * 0.05 + round as f32).sin()).collect();
                let msg_a = qc.quantize(&delta, &mut rng_a);
                let msg_b = qc.quantize(&delta, &mut rng_b);
                let a = flat.ingest(&msg_a, round % 4).unwrap();
                let p = match edge.ingest(&msg_b, round % 4).unwrap() {
                    AggOutcome::Forward(p) => p,
                    other => panic!("trivial edge must forward, got {other:?}"),
                };
                assert_eq!(p.count, 1);
                let b = root.ingest_partial(&p.msg, p.count, &p.staleness, pc).unwrap();
                match (a, b) {
                    (ServerStep::Stepped(ba), ServerStep::Stepped(bb)) => {
                        assert_eq!(ba[0].msg.payload, bb[0].msg.payload, "S={shards} broadcast");
                        assert_eq!(ba[0].bytes, bb[0].bytes);
                        assert_eq!(ba[0].t, bb[0].t);
                    }
                    (ServerStep::Buffered, ServerStep::Buffered) => {}
                    _ => panic!("S={shards}: step/buffer divergence"),
                }
            }
            assert_eq!(flat.model(), root.model(), "S={shards} model");
            assert_eq!(
                flat.client_snapshot().as_slice(),
                root.client_snapshot().as_slice(),
                "S={shards} hidden state"
            );
            assert_eq!(flat.t(), root.t());
            // staleness accounting survives the tree (mean over the
            // merged histograms == mean over the flat uploads)
            assert_eq!(flat.staleness_mean(), root.staleness_mean(), "S={shards}");
            assert_eq!(flat.staleness_max, root.staleness_max);
        }
    }

    #[test]
    fn trivial_tree_with_clipping_matches_flat_server_with_clipping() {
        // Edge-clipped partials must replay bit-identical to a flat
        // server clipping the same updates: the clip scale folds into
        // the ingest weight at whichever node sees the raw update, and
        // the root ingests partials verbatim (never re-clipped).
        let mut base = cfg("qafel", 2);
        base.quant.client = "qsgd:8".into();
        base.quant.server = "qsgd:4".into();
        base.fl.staleness_scaling = true;
        base.fl.robust.enabled = true;
        base.fl.robust.clip_norm = 2.0;
        let d = 128 + 19;
        for shards in [1usize, 4] {
            let mut cfg = base.clone();
            cfg.fl.shards = shards;
            let mut flat = Server::build(&cfg, vec![0.0; d], 7).unwrap();
            // the root of the tree must NOT clip partials, so it runs
            // with the same robust config but only sees pre-clipped
            // partial aggregates
            let mut root = Server::build(&cfg, vec![0.0; d], 7).unwrap();
            let pc = root.register_partial_codec("none").unwrap();
            let mut edge = EdgeAggregator::new(
                d, 1, "none", &cfg.quant.client, cfg.fl.algorithm,
                cfg.fl.staleness_scaling, ShardPool::new(shards), 99,
            )
            .unwrap()
            .with_robust(&cfg.fl.robust);
            let qc = parse_spec("qsgd:8").unwrap();
            let mut rng_a = Prng::new(11);
            let mut rng_b = Prng::new(11);
            for round in 0..10u64 {
                let scale = if round % 2 == 0 { 30.0 } else { 0.1 }; // half oversized
                let delta: Vec<f32> =
                    (0..d).map(|i| scale * ((i as f32) * 0.05 + round as f32).sin()).collect();
                let msg_a = qc.quantize(&delta, &mut rng_a);
                let msg_b = qc.quantize(&delta, &mut rng_b);
                let a = flat.ingest(&msg_a, round % 3).unwrap();
                let p = match edge.ingest(&msg_b, round % 3).unwrap() {
                    AggOutcome::Forward(p) => p,
                    other => panic!("trivial edge must forward, got {other:?}"),
                };
                let b = root.ingest_partial(&p.msg, p.count, &p.staleness, pc).unwrap();
                match (a, b) {
                    (ServerStep::Stepped(ba), ServerStep::Stepped(bb)) => {
                        assert_eq!(ba[0].msg.payload, bb[0].msg.payload, "S={shards} broadcast");
                    }
                    (ServerStep::Buffered, ServerStep::Buffered) => {}
                    _ => panic!("S={shards}: step/buffer divergence"),
                }
            }
            assert_eq!(flat.model(), root.model(), "S={shards} model");
            // attribution: the edge counted exactly what the flat
            // server counted, and the root clipped nothing itself
            assert_eq!(flat.clipped_updates, edge.clipped_updates, "S={shards}");
            assert!(edge.clipped_updates > 0);
            assert_eq!(root.clipped_updates, 0);
        }
    }

    #[test]
    fn root_rejects_bad_partials_loudly() {
        let cfg = cfg("fedbuff", 2);
        let mut root = Server::build(&cfg, vec![0.0; 8], 1).unwrap();
        // no partial codec registered yet
        let p = identity_msg(&[1.0; 8]);
        let h = StalenessHist::default();
        assert!(root.ingest_partial(&p, 1, &h, 0).is_err());
        let pc = root.register_partial_codec("none").unwrap();
        // dedup like client codecs
        assert_eq!(root.register_partial_codec("identity").unwrap(), pc);
        assert_eq!(root.num_partial_codecs(), 1);
        assert_eq!(root.partial_codec_name(pc), "none");
        // zero-count partial is rejected
        assert!(root.ingest_partial(&p, 0, &h, pc).is_err());
        // wrong dimension / wrong size fail before touching the buffer
        let short = identity_msg(&[1.0; 4]);
        assert!(root.ingest_partial(&short, 1, &h, pc).is_err());
        let mut trunc = identity_msg(&[1.0; 8]);
        trunc.payload.pop();
        assert!(root.ingest_partial(&trunc, 1, &h, pc).is_err());
        assert_eq!(root.comm.uploads, 0);
    }

    #[test]
    fn aggregator_trait_is_object_safe_across_node_types() {
        let cfg = cfg("fedbuff", 2);
        let root = Server::build(&cfg, vec![0.0; 4], 1).unwrap();
        let edge = EdgeAggregator::new(
            4, 2, "none", "none", Algorithm::FedBuff, false,
            ShardPool::sequential(), 1,
        )
        .unwrap();
        let mut nodes: Vec<Box<dyn Aggregator>> = vec![Box::new(root), Box::new(edge)];
        for node in &mut nodes {
            assert_eq!(node.d(), 4);
            let out = node.ingest_update(&identity_msg(&[1.0; 4]), 0, 0).unwrap();
            assert!(matches!(out, AggOutcome::Buffered));
        }
    }
}
