//! Algorithm 1 — QAFeL-server (and its baselines).
//!
//! ```text
//! x̂^0 <- x^0                                  (shared hidden state)
//! repeat:
//!   on client update Δ_n (staleness τ_n):
//!     Δ̄ += w(τ_n) · dequant(Δ_n);  k += 1
//!   if k == K:
//!     Δ̄ /= K
//!     v <- β v + Δ̄                            (server momentum, App. D)
//!     x^{t+1} <- x^t + η_g v
//!     broadcast q^t = Q_s(x^{t+1} - x̂^t)      (hidden-state increment)
//!     x̂^{t+1} <- x̂^t + q^t                    (same update on clients)
//!     Δ̄ <- 0; k <- 0; t += 1
//! ```
//!
//! `w(τ) = 1/sqrt(1+τ)` when staleness scaling is on (Fig. 3 runs),
//! otherwise 1. With `Q_c = Q_s = identity` this is exactly FedBuff; with
//! `hidden_state = false` the server instead broadcasts `Q_s(x^{t+1})`
//! directly (the DirectQuant baseline), which propagates quantization
//! error proportional to ‖x‖ rather than ‖x^{t+1} − x̂^t‖.
//!
//! **Sharded aggregation pipeline** (`cfg.fl.shards = S`, see
//! DESIGN_SHARDING.md): every per-coordinate stage of the step —
//! client-update accumulate, the momentum + η_g apply, the hidden-state
//! diff, the `Q_s` encode and the x̂ advance — runs in parallel over S
//! contiguous ranges of the model vector on a **persistent
//! [`ShardPool`]** owned by the server (S − 1 long-lived workers + the
//! calling thread; zero thread spawns per step in steady state). Ranges
//! are aligned to the codec's bucket structure so per-bucket QSGD norms
//! stay shard-local and the packed body is byte-aligned at every seam;
//! quantizer noise is drawn once, sequentially, so the broadcast bytes
//! are **bit-identical for every S** (S = 1 runs fully inline with zero
//! threading overhead). Every built-in codec shards — qsgd/identity by
//! stitching per-range parts, top_k by a cross-shard candidate merge,
//! rand_k through per-bucket index streams.

use crate::config::{Algorithm, Config, RobustConfig};
use crate::metrics::CommMetrics;
use crate::quant::{parse_spec, sharded, QuantizedMsg, Quantizer};
use crate::telemetry::event::{hex_f32s, hex_u64, parse_hex_f32s, parse_hex_u64};
use crate::telemetry::{self, StageTimings};
use crate::util::json::Json;
use crate::util::pool::{ShardPool, Task};
use crate::util::prng::Prng;
use crate::util::shard::span_for;
use crate::util::vecf;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

/// A server->clients broadcast message.
#[derive(Clone, Debug)]
pub struct Broadcast {
    /// Server step index t after this update.
    pub t: u64,
    /// Wire bytes of the broadcast payload.
    pub bytes: usize,
    /// The message itself (applied by `ClientLogic`/net clients; the
    /// simulator applies it implicitly through the shared hidden state).
    pub msg: QuantizedMsg,
    /// True if the message carries the absolute model (DirectQuant mode)
    /// rather than a hidden-state increment.
    pub absolute: bool,
    /// Downlink family id this broadcast was encoded with (0 = the
    /// default `Q_s`; further ids are per-tier presets registered via
    /// [`Server::register_server_codec`]).
    pub codec: usize,
}

/// Outcome of ingesting one client update.
#[derive(Clone, Debug)]
pub enum ServerStep {
    /// Update buffered; buffer not yet full.
    Buffered,
    /// Buffer filled: server step taken, one broadcast emitted per
    /// downlink family (family 0 first; a single-family server emits
    /// exactly one, as before per-tier downlink codecs existed).
    Stepped(Vec<Broadcast>),
}

/// One downlink family: a broadcast codec `Q_s` and the shared hidden
/// state x̂ it maintains. QAFeL's hidden-state construction is what makes
/// per-tier downlink quantization safe: every tier tracks its own
/// `x̂_f^{t+1} = x̂_f^t + Q_{s,f}(x^{t+1} − x̂_f^t)`, so quantization error
/// never propagates across tiers (or into the model).
struct DownlinkFamily {
    codec: Box<dyn Quantizer>,
    /// This family's shared hidden state x̂^t (reference replica; clients
    /// of this family hold copies in net mode). `Arc` so in-flight
    /// clients can snapshot it for free.
    x_hat: Arc<Vec<f32>>,
}

/// The QAFeL server state machine.
pub struct Server {
    // --- configuration -----------------------------------------------------
    k_buffer: usize,
    eta_g: f32,
    beta: f32,
    staleness_scaling: bool,
    hidden_state_mode: bool,
    /// Persistent worker pool for the S aggregation shards (S = 1 is a
    /// no-thread pool; every stage runs inline). Shared with the sim's
    /// eval path via [`Server::pool`].
    pool: Arc<ShardPool>,
    /// Downlink families: one broadcast codec `Q_s` plus its own shared
    /// hidden-state replica x̂ per *distinct resolved server codec*.
    /// Family 0 is built from `cfg.quant.server` (resolved per
    /// algorithm) at construction; further families are per-tier
    /// `quant_server` presets added by
    /// [`Server::register_server_codec`], deduplicated like client
    /// presets. Every step broadcasts once per family (family 0 first,
    /// drawing quantizer noise sequentially from the shared RNG), so a
    /// single-family server is bit-identical to the pre-family engine.
    families: Vec<DownlinkFamily>,
    /// Codecs for *decoding* client uploads. Id 0 is built from
    /// `cfg.quant.client` (resolved per algorithm) at construction;
    /// further ids are per-tier presets added by
    /// [`Server::register_client_codec`]. A mismatched upload fails
    /// loudly in [`Server::ingest_from`].
    client_codecs: Vec<Box<dyn Quantizer>>,
    /// Codecs for decoding *partial aggregates* forwarded by edge
    /// aggregators (the tree-of-leaders path,
    /// `crate::coordinator::aggregator`). Registered explicitly by
    /// [`Server::register_partial_codec`]; starts empty — a flat server
    /// never decodes partials. Specs are parsed raw (no per-algorithm
    /// resolution): a partial carries already-decoded buffer values.
    partial_codecs: Vec<Box<dyn Quantizer>>,
    algorithm: Algorithm,
    /// Robust-aggregation knobs (`[fl.robust]`). All-off by default;
    /// the plain buffered mean runs byte-identically when disabled.
    robust: RobustConfig,
    // --- state ---------------------------------------------------------------
    d: usize,
    /// Server model x^t.
    x: Vec<f32>,
    /// Momentum buffer v.
    momentum: Vec<f32>,
    /// Aggregation buffer Δ̄ (pre-division).
    buffer: Vec<f32>,
    k_filled: usize,
    t: u64,
    /// Randomness for the server quantizer.
    rng: Prng,
    /// Scratch for x^{t+1} - x̂^t.
    diff: Vec<f32>,
    /// Scratch for one decoded update when a robust stage needs its
    /// values (norm for clipping, row storage for trimming). Empty when
    /// robust is off — the plain path never allocates it.
    robust_scratch: Vec<f32>,
    /// Decoded, w·clip-scaled rows of the current buffer, pending the
    /// coordinate-wise trimmed mean (trim mode only; ingest order).
    trim_rows: Vec<Vec<f32>>,
    /// Retired row allocations, reused across steps.
    trim_spare: Vec<Vec<f32>>,
    /// Per-row verdicts of the *last* step's trimmed mean, in ingest
    /// order: true = the row was excluded at more than half of its
    /// coordinates (counted as one `trimmed_updates`).
    last_trim_flags: Vec<bool>,
    /// Did the most recent `ingest_from` shrink its update's norm?
    last_ingest_clipped: bool,
    /// Updates shrunk by the norm clip so far (normalization counts
    /// only updates that came in *over* `clip_norm`).
    pub clipped_updates: u64,
    /// Rows excluded at a majority of coordinates by the trimmed mean.
    pub trimmed_updates: u64,
    // --- accounting --------------------------------------------------------
    pub comm: CommMetrics,
    /// Per-stage wall time of the aggregation pipeline (`steps` counts
    /// always; the ns fields accumulate only while `telemetry::enabled`).
    stages: StageTimings,
    /// Staleness histogram data (max observed, sum for mean).
    pub staleness_max: u64,
    pub staleness_sum: u64,
    /// Number of staleness values behind `staleness_sum`. Equals
    /// `comm.uploads` on the flat path; a partial aggregate is *one*
    /// wire upload carrying *count* staleness values, so the mean needs
    /// its own denominator.
    pub staleness_n: u64,
}

impl Server {
    /// Build from the experiment config and the initial model x^0.
    ///
    /// Both codecs are constructed here: `Q_s` from the algorithm preset
    /// and `Q_c` from `cfg.quant.client` (identity for the
    /// full-precision baselines) — a server is never left with a
    /// default codec that silently mis-decodes uploads.
    pub fn new(cfg: &Config, x0: Vec<f32>, seed: u64) -> Result<Server> {
        let d = x0.len();
        // Algorithm presets (DESIGN.md S3-S5)
        let (quant_s_spec, k_buffer, hidden_state_mode, staleness_scaling) =
            match cfg.fl.algorithm {
                Algorithm::Qafel => (
                    cfg.quant.server.clone(),
                    cfg.fl.buffer_size,
                    true,
                    cfg.fl.staleness_scaling,
                ),
                Algorithm::FedBuff => (
                    "none".to_string(),
                    cfg.fl.buffer_size,
                    true,
                    cfg.fl.staleness_scaling,
                ),
                Algorithm::FedAsync => ("none".to_string(), 1, true, true),
                Algorithm::DirectQuant => (
                    cfg.quant.server.clone(),
                    cfg.fl.buffer_size,
                    false,
                    cfg.fl.staleness_scaling,
                ),
            };
        let quant_s = parse_spec(&quant_s_spec)?;
        let quant_c = parse_spec(&client_codec_spec(&cfg.quant.client, cfg.fl.algorithm))?;
        let robust = cfg.fl.robust.clone();
        let needs_scratch = robust.clip_enabled() || robust.trim_enabled();
        Ok(Server {
            client_codecs: vec![quant_c],
            partial_codecs: Vec::new(),
            algorithm: cfg.fl.algorithm,
            k_buffer,
            eta_g: cfg.fl.server_lr,
            beta: cfg.fl.server_momentum,
            staleness_scaling,
            hidden_state_mode,
            pool: ShardPool::new(cfg.fl.shards.max(1)),
            families: vec![DownlinkFamily { codec: quant_s, x_hat: Arc::new(x0.clone()) }],
            d,
            momentum: vec![0.0; d],
            buffer: vec![0.0; d],
            x: x0,
            k_filled: 0,
            t: 0,
            rng: Prng::new(seed).stream("server-quant"),
            diff: vec![0.0; d],
            robust_scratch: if needs_scratch { vec![0.0; d] } else { Vec::new() },
            trim_rows: Vec::new(),
            trim_spare: Vec::new(),
            last_trim_flags: Vec::new(),
            last_ingest_clipped: false,
            clipped_updates: 0,
            trimmed_updates: 0,
            robust,
            comm: CommMetrics::default(),
            stages: StageTimings::default(),
            staleness_max: 0,
            staleness_sum: 0,
            staleness_n: 0,
        })
    }

    /// Server step count t.
    pub fn t(&self) -> u64 {
        self.t
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Buffer size K.
    pub fn k_buffer(&self) -> usize {
        self.k_buffer
    }

    /// Aggregation shards S.
    pub fn shards(&self) -> usize {
        self.pool.shards()
    }

    /// The server's persistent shard worker pool — reused by the sim's
    /// eval path and anything else that decodes at model scale.
    pub fn pool(&self) -> &Arc<ShardPool> {
        &self.pool
    }

    /// The state a newly sampled client copies (Algorithm 2 line 1):
    /// the shared hidden state in QAFeL/FedBuff mode, or the latest
    /// direct-quantized model in DirectQuant mode. Cheap Arc clone.
    pub fn client_snapshot(&self) -> Arc<Vec<f32>> {
        self.families[0].x_hat.clone()
    }

    /// The hidden-state snapshot of downlink family `f` — what a client
    /// of a tier resolved to that family copies at round start. Family 0
    /// is [`Server::client_snapshot`].
    pub fn family_snapshot(&self, f: usize) -> Arc<Vec<f32>> {
        self.families[f].x_hat.clone()
    }

    /// True server model x^t (for evaluation — the paper evaluates the
    /// server model).
    pub fn model(&self) -> &[f32] {
        &self.x
    }

    /// Mean observed staleness so far — over every client update the
    /// tree saw (a partial aggregate contributes its whole histogram,
    /// not one value).
    pub fn staleness_mean(&self) -> f64 {
        if self.staleness_n == 0 {
            0.0
        } else {
            self.staleness_sum as f64 / self.staleness_n as f64
        }
    }

    /// Register an extra client-upload codec (a per-tier quantizer
    /// preset) and return its id for [`Server::ingest_from`]. The spec
    /// is resolved per algorithm like `cfg.quant.client` (full-precision
    /// baselines decode identity regardless of preset) and identical
    /// resolved codecs are deduplicated — registering the default spec
    /// returns 0. Registration order is the wire contract: clients and
    /// server must register presets in the same order to agree on ids.
    pub fn register_client_codec(&mut self, spec: &str) -> Result<usize> {
        let resolved = client_codec_spec(spec, self.algorithm);
        let codec = parse_spec(&resolved)?;
        if let Some(i) = self.client_codecs.iter().position(|c| c.name() == codec.name()) {
            return Ok(i);
        }
        self.client_codecs.push(codec);
        Ok(self.client_codecs.len() - 1)
    }

    /// Register every tier's `quant_client` preset from the config, in
    /// tier order — the same order (and therefore the same ids) the
    /// scenario engine uses, so a TCP leader and the simulator agree on
    /// the codec registry for the same config. Returns the per-tier
    /// codec ids (0, the default codec, for tiers without a preset).
    pub fn register_tier_presets(&mut self, cfg: &Config) -> Result<Vec<usize>> {
        cfg.resolved_tiers()
            .iter()
            .map(|t| match &t.quant_client {
                Some(spec) => self.register_client_codec(spec),
                None => Ok(0),
            })
            .collect()
    }

    /// Number of registered client codecs (>= 1; id 0 is the default).
    pub fn num_client_codecs(&self) -> usize {
        self.client_codecs.len()
    }

    /// Register a per-tier *downlink* codec preset and return its
    /// family id. The spec is resolved per algorithm like
    /// `cfg.quant.server` (full-precision baselines broadcast identity
    /// regardless of preset) and identical resolved codecs are
    /// deduplicated — registering the default spec returns 0, so tiers
    /// without a `quant_server` preset share family 0 and no-preset
    /// configs keep exactly one family. Registration order is the wire
    /// contract, like client codecs. A *new* family seeds its x̂ from
    /// x̂^0, so families must be registered before the first server step
    /// — registering one later fails loudly (dedup hits stay fine).
    pub fn register_server_codec(&mut self, spec: &str) -> Result<usize> {
        let resolved = server_codec_spec(spec, self.algorithm);
        let codec = parse_spec(&resolved)?;
        if let Some(i) = self.families.iter().position(|f| f.codec.name() == codec.name()) {
            return Ok(i);
        }
        if self.t > 0 || self.k_filled > 0 {
            bail!(
                "server: downlink codec '{}' registered at t={} with {} buffered update(s) — \
                 families must be registered before the first ingest so every x̂ starts at x̂^0",
                codec.name(),
                self.t,
                self.k_filled
            );
        }
        let x_hat = self.families[0].x_hat.clone();
        self.families.push(DownlinkFamily { codec, x_hat });
        Ok(self.families.len() - 1)
    }

    /// Register every tier's `quant_server` preset from the config, in
    /// tier order — the same order (and therefore the same family ids)
    /// the scenario engine uses, so a TCP leader and the simulator agree
    /// on the downlink registry for the same config. Returns the
    /// per-tier family ids (0, the default `Q_s`, for tiers without a
    /// preset).
    pub fn register_tier_server_presets(&mut self, cfg: &Config) -> Result<Vec<usize>> {
        cfg.resolved_tiers()
            .iter()
            .map(|t| match &t.quant_server {
                Some(spec) => self.register_server_codec(spec),
                None => Ok(0),
            })
            .collect()
    }

    /// Number of downlink families (>= 1; family 0 is the default).
    pub fn num_server_codecs(&self) -> usize {
        self.families.len()
    }

    /// Spec name of a downlink family's codec.
    pub fn server_codec_name(&self, f: usize) -> String {
        self.families[f].codec.name()
    }

    /// Expected wire bytes of one broadcast from downlink family `f` at
    /// this model dimension — what sizes that family's `UpdateLog`
    /// (each tier's log must use its *own* codec's increment size, or
    /// cheap-codec tiers evict history at the wrong horizon).
    pub fn server_codec_bytes(&self, f: usize) -> usize {
        self.families[f].codec.expected_bytes(self.d)
    }

    /// Spec name of a registered client codec.
    pub fn client_codec_name(&self, codec: usize) -> String {
        self.client_codecs[codec].name()
    }

    /// Route an upload to a registered codec by its exact payload size —
    /// for ingest paths that receive raw wire messages without a codec
    /// tag (e.g. a transport that negotiates codecs by size). Fails when
    /// no registered codec matches or when two registered codecs share
    /// the same wire size at this model dimension (ambiguous: the caller
    /// must tag messages with codec ids instead).
    pub fn codec_for_bytes(&self, wire_bytes: usize) -> Result<usize> {
        let mut found: Option<usize> = None;
        for (i, c) in self.client_codecs.iter().enumerate() {
            if c.expected_bytes(self.d) == wire_bytes {
                if let Some(prev) = found {
                    bail!(
                        "server: upload size {wire_bytes}B is ambiguous between client \
                         codecs '{}' (#{prev}) and '{}' (#{i}) at d={} — tag uploads \
                         with a codec id",
                        self.client_codecs[prev].name(),
                        c.name(),
                        self.d
                    );
                }
                found = Some(i);
            }
        }
        found.ok_or_else(|| {
            anyhow::anyhow!(
                "server: no registered client codec produces {wire_bytes}B at d={}",
                self.d
            )
        })
    }

    /// Ingest one quantized client update (Algorithm 1 lines 5–16),
    /// decoded with the default client codec (id 0).
    ///
    /// `staleness` is the number of server steps taken since the client
    /// copied its snapshot (τ_n(t) in the paper).
    pub fn ingest(&mut self, update: &QuantizedMsg, staleness: u64) -> Result<ServerStep> {
        self.ingest_from(update, staleness, 0)
    }

    /// Ingest one client update encoded with the registered codec
    /// `codec` — the heterogeneous-ingest path for per-tier quantizer
    /// presets. Payloads of different tiers may carry different wire
    /// formats in the same buffer; each is decoded (and size-checked)
    /// with its own codec on the shared [`ShardPool`].
    pub fn ingest_from(
        &mut self,
        update: &QuantizedMsg,
        staleness: u64,
        codec: usize,
    ) -> Result<ServerStep> {
        let quant_c = self
            .client_codecs
            .get(codec)
            .ok_or_else(|| anyhow::anyhow!("server: unknown client codec id {codec}"))?;
        // Fail loudly on codec mismatch before touching the buffer: a
        // wrong-sized payload means the client encoded with a different
        // quantizer than the server decodes with.
        if update.d != self.d {
            bail!(
                "server: upload dimension {} != model dimension {}",
                update.d,
                self.d
            );
        }
        let expect = quant_c.expected_bytes(self.d);
        if update.wire_bytes() != expect {
            bail!(
                "server: upload payload is {} bytes but client codec '{}' \
                 expects {} at d={} — client and server quantizer specs \
                 disagree",
                update.wire_bytes(),
                quant_c.name(),
                expect,
                self.d
            );
        }
        self.comm.record_upload(update.wire_bytes());
        self.staleness_sum += staleness;
        self.staleness_max = self.staleness_max.max(staleness);
        self.staleness_n += 1;

        // scale down stale updates by 1/sqrt(1+τ) (Appendix D / Xie et al.)
        let w = if self.staleness_scaling {
            1.0 / ((1.0 + staleness as f64).sqrt() as f32)
        } else {
            1.0
        };
        // Dequantize straight into the aggregation buffer (no temp
        // alloc), shard-parallel on the persistent pool when S > 1.
        let quant_c = self.client_codecs[codec].as_ref();
        let timer = telemetry::span_start();
        self.last_ingest_clipped = false;
        if self.robust.clip_enabled() || self.robust.trim_enabled() {
            // Robust path: decode to scratch first — clipping needs the
            // update's norm and trimming needs its values. The norm is
            // a sequential f64 reduction over the decoded vector and
            // the decode itself is shard-bit-identical, so every
            // robust quantity is independent of `fl.shards`.
            sharded::dequantize_into(quant_c, update, &mut self.robust_scratch, &self.pool)?;
            let mut w_eff = w;
            if self.robust.clip_enabled() {
                let norm = vecf::norm2(&self.robust_scratch);
                let clip = self.robust.clip_norm;
                if norm > clip {
                    self.last_ingest_clipped = true;
                    self.clipped_updates += 1;
                }
                if norm > 0.0 && (self.robust.normalize || norm > clip) {
                    // scale = clip/‖u‖ (normalize) or min(1, clip/‖u‖),
                    // folded into the staleness weight so the actual
                    // accumulate runs unchanged.
                    w_eff *= (clip / norm) as f32;
                }
            }
            if self.robust.trim_enabled() {
                // Store the w·clip-scaled row; the trimmed mean runs
                // over the whole buffer when it fills (`step`).
                let mut row = self.trim_spare.pop().unwrap_or_default();
                row.clear();
                row.extend(self.robust_scratch.iter().map(|&v| v * w_eff));
                self.trim_rows.push(row);
            } else {
                sharded::accumulate(quant_c, update, w_eff, &mut self.buffer, &self.pool)?;
            }
        } else {
            sharded::accumulate(quant_c, update, w, &mut self.buffer, &self.pool)?;
        }
        self.stages.accumulate_ns += telemetry::span_ns(timer);
        self.k_filled += 1;

        if self.k_filled < self.k_buffer {
            return Ok(ServerStep::Buffered);
        }
        self.step().map(ServerStep::Stepped)
    }

    /// Register a codec for decoding partial aggregates forwarded by
    /// edge aggregators, returning its id for
    /// [`Server::ingest_partial`]. The spec is parsed raw (partials
    /// carry already-decoded buffer values, so per-algorithm client
    /// resolution does not apply) and deduplicated by name —
    /// registration order is the wire contract, like client codecs.
    pub fn register_partial_codec(&mut self, spec: &str) -> Result<usize> {
        let codec = parse_spec(spec)?;
        if let Some(i) = self.partial_codecs.iter().position(|c| c.name() == codec.name()) {
            return Ok(i);
        }
        self.partial_codecs.push(codec);
        Ok(self.partial_codecs.len() - 1)
    }

    /// Number of registered partial codecs (0 on a flat server).
    pub fn num_partial_codecs(&self) -> usize {
        self.partial_codecs.len()
    }

    /// Spec name of a registered partial codec.
    pub fn partial_codec_name(&self, codec: usize) -> String {
        self.partial_codecs[codec].name()
    }

    /// Ingest a partial aggregate forwarded by an edge aggregator — the
    /// tree-of-leaders ingest path. `update` is the edge's
    /// count-weighted buffer encoded with registered partial codec
    /// `codec`; `count` is how many client updates it folds (the buffer
    /// fill advances by `count` slots); `staleness` is the edge's
    /// histogram over those updates, merged into the server's
    /// accounting. Staleness weights `w(τ)` were already applied at the
    /// edge, so the partial accumulates with weight exactly 1.0 — this
    /// is what makes a trivial tree bit-identical to the flat server.
    ///
    /// For exact flat equivalence, K should be a multiple of the edge
    /// buffer size B; an overshooting partial (`k_filled > K`) still
    /// triggers exactly one step with the configured `1/K` scaling and
    /// the overshoot is absorbed into that step's buffer.
    pub fn ingest_partial(
        &mut self,
        update: &QuantizedMsg,
        count: u32,
        staleness: &crate::scenario::metrics::StalenessHist,
        codec: usize,
    ) -> Result<ServerStep> {
        let quant_p = self
            .partial_codecs
            .get(codec)
            .ok_or_else(|| anyhow::anyhow!("server: unknown partial codec id {codec}"))?;
        if update.d != self.d {
            bail!(
                "server: partial dimension {} != model dimension {}",
                update.d,
                self.d
            );
        }
        let expect = quant_p.expected_bytes(self.d);
        if update.wire_bytes() != expect {
            bail!(
                "server: partial payload is {} bytes but partial codec '{}' \
                 expects {} at d={} — edge and server partial-codec specs \
                 disagree",
                update.wire_bytes(),
                quant_p.name(),
                expect,
                self.d
            );
        }
        if count == 0 {
            bail!("server: partial aggregate with count 0");
        }
        if self.robust.trim_enabled() {
            // A partial has already collapsed its rows into one vector;
            // a coordinate-wise trimmed mean needs the individual
            // client rows back. Config validation rejects trim+edges,
            // so reaching this means the caller bypassed it.
            bail!(
                "server: [fl.robust] trim_frac is incompatible with edge partial \
                 aggregates — trimming needs individual client rows (clip at the \
                 edges instead)"
            );
        }
        self.comm.record_upload(update.wire_bytes());
        self.staleness_sum += staleness.sum;
        self.staleness_max = self.staleness_max.max(staleness.max);
        self.staleness_n += staleness.n;

        let quant_p = self.partial_codecs[codec].as_ref();
        let timer = telemetry::span_start();
        sharded::accumulate(quant_p, update, 1.0, &mut self.buffer, &self.pool)?;
        self.stages.accumulate_ns += telemetry::span_ns(timer);
        self.k_filled += count as usize;

        if self.k_filled < self.k_buffer {
            return Ok(ServerStep::Buffered);
        }
        self.step().map(ServerStep::Stepped)
    }

    /// The server step proper (Algorithm 1 lines 9–16), executed when
    /// the buffer fills. Stages run shard-parallel; see the module docs
    /// for the determinism contract. Emits one broadcast per downlink
    /// family: family 0 encodes first and every family draws quantizer
    /// noise sequentially from the shared server RNG, so a
    /// single-family server's draws (and therefore its bytes) are
    /// unchanged from the pre-family engine.
    fn step(&mut self) -> Result<Vec<Broadcast>> {
        if self.robust.trim_enabled() {
            self.apply_trimmed_mean();
        }
        let inv_k = 1.0 / self.k_buffer as f32;
        let (beta, eta_g) = (self.beta, self.eta_g);
        let shards = self.pool.shards();
        let span = span_for(self.d, shards, 1);

        // v <- beta * v + delta_bar ; x <- x + eta_g * v ; delta_bar <- 0
        // (purely elementwise: identical floats for any shard split)
        let timer = telemetry::span_start();
        if shards > 1 && span < self.d {
            let tasks: Vec<Task<'_>> = self
                .momentum
                .chunks_mut(span)
                .zip(self.buffer.chunks_mut(span))
                .zip(self.x.chunks_mut(span))
                .map(|((m, b), x)| {
                    Box::new(move || {
                        for i in 0..m.len() {
                            m[i] = beta * m[i] + b[i] * inv_k;
                            x[i] += eta_g * m[i];
                            b[i] = 0.0;
                        }
                    }) as Task<'_>
                })
                .collect();
            self.pool.run(tasks);
        } else {
            for i in 0..self.d {
                self.momentum[i] = self.beta * self.momentum[i] + self.buffer[i] * inv_k;
                self.x[i] += self.eta_g * self.momentum[i];
            }
            vecf::zero(&mut self.buffer);
        }
        self.stages.momentum_ns += telemetry::span_ns(timer);
        self.k_filled = 0;
        self.t += 1;
        self.stages.steps += 1;

        let mut out = Vec::with_capacity(self.families.len());
        for f in 0..self.families.len() {
            let broadcast = if self.hidden_state_mode {
                // q_f^t = Q_{s,f}(x^{t+1} - x̂_f^t); x̂_f^{t+1} = x̂_f^t + q_f^t
                let timer = telemetry::span_start();
                if shards > 1 && span < self.d {
                    let tasks: Vec<Task<'_>> = self
                        .diff
                        .chunks_mut(span)
                        .zip(self.x.chunks(span))
                        .zip(self.families[f].x_hat.chunks(span))
                        .map(|((out, a), b)| Box::new(move || vecf::sub(out, a, b)) as Task<'_>)
                        .collect();
                    self.pool.run(tasks);
                } else {
                    vecf::sub(&mut self.diff, &self.x, &self.families[f].x_hat);
                }
                self.stages.diff_ns += telemetry::span_ns(timer);
                let timer = telemetry::span_start();
                let msg = sharded::quantize(
                    self.families[f].codec.as_ref(),
                    &self.diff,
                    &mut self.rng,
                    &self.pool,
                );
                self.stages.encode_ns += telemetry::span_ns(timer);
                let bytes = msg.wire_bytes();
                self.comm.record_broadcast(bytes);
                let timer = telemetry::span_start();
                let fam = &mut self.families[f];
                let x_hat = Arc::make_mut(&mut fam.x_hat);
                sharded::accumulate(fam.codec.as_ref(), &msg, 1.0, x_hat, &self.pool)?;
                self.stages.advance_ns += telemetry::span_ns(timer);
                Broadcast { t: self.t, bytes, msg, absolute: false, codec: f }
            } else {
                // DirectQuant baseline: broadcast Q_{s,f}(x^{t+1}) itself
                let timer = telemetry::span_start();
                let msg = sharded::quantize(
                    self.families[f].codec.as_ref(),
                    &self.x,
                    &mut self.rng,
                    &self.pool,
                );
                self.stages.encode_ns += telemetry::span_ns(timer);
                let bytes = msg.wire_bytes();
                self.comm.record_broadcast(bytes);
                let timer = telemetry::span_start();
                let fam = &mut self.families[f];
                let x_hat = Arc::make_mut(&mut fam.x_hat);
                sharded::dequantize_into(fam.codec.as_ref(), &msg, x_hat, &self.pool)?;
                self.stages.advance_ns += telemetry::span_ns(timer);
                Broadcast { t: self.t, bytes, msg, absolute: true, codec: f }
            };
            out.push(broadcast);
        }
        Ok(out)
    }

    /// Coordinate-wise trimmed mean over the buffered rows, written into
    /// `self.buffer` scaled by K so the unchanged `buffer/K` step applies
    /// exactly the trimmed mean. Per coordinate, the g = ⌊trim_frac·R⌋
    /// smallest and largest of the R row values are dropped and the rest
    /// averaged (f64, in sorted order — every per-coordinate quantity is
    /// coordinate-local, so the result is bit-identical for any shard
    /// split; ties break by ingest order via `total_cmp` + index).
    /// Rows excluded at more than half of their coordinates are flagged
    /// in `last_trim_flags` (ingest order) and counted as trimmed.
    fn apply_trimmed_mean(&mut self) {
        let r_n = self.trim_rows.len();
        self.last_trim_flags.clear();
        if r_n == 0 {
            return;
        }
        let g = (self.robust.trim_frac * r_n as f64).floor() as usize;
        let keep = (r_n - 2 * g) as f64;
        let k = self.k_buffer as f64;
        let d = self.d;
        let span = span_for(d, self.pool.shards(), 1);
        let chunks = d.div_ceil(span);
        let rows = &self.trim_rows;
        // per-chunk exclusion tallies (integer, order-independent), so
        // every lane writes its own slice and the merge is exact
        let mut excluded: Vec<Vec<u32>> = (0..chunks).map(|_| vec![0u32; r_n]).collect();
        let tasks: Vec<Task<'_>> = self
            .buffer
            .chunks_mut(span)
            .zip(excluded.iter_mut())
            .enumerate()
            .map(|(ci, (buf, excl))| {
                Box::new(move || {
                    let mut order: Vec<usize> = Vec::with_capacity(r_n);
                    let mut vals = vec![0.0f32; r_n];
                    for (j, out) in buf.iter_mut().enumerate() {
                        let i = ci * span + j;
                        for (r, v) in vals.iter_mut().enumerate() {
                            *v = rows[r][i];
                        }
                        order.clear();
                        order.extend(0..r_n);
                        order.sort_by(|&a, &b| vals[a].total_cmp(&vals[b]).then(a.cmp(&b)));
                        for &r in order[..g].iter().chain(&order[r_n - g..]) {
                            excl[r] += 1;
                        }
                        let mut sum = 0.0f64;
                        for &r in &order[g..r_n - g] {
                            sum += vals[r] as f64;
                        }
                        *out = ((sum / keep) * k) as f32;
                    }
                }) as Task<'_>
            })
            .collect();
        self.pool.run(tasks);
        for r in 0..r_n {
            let total: u64 = excluded.iter().map(|e| e[r] as u64).sum();
            let trimmed = total * 2 > d as u64;
            if trimmed {
                self.trimmed_updates += 1;
            }
            self.last_trim_flags.push(trimmed);
        }
        self.trim_spare.append(&mut self.trim_rows);
    }

    /// The robust-aggregation knobs this server was built with.
    pub fn robust(&self) -> &RobustConfig {
        &self.robust
    }

    /// Did the most recent `ingest_from` shrink its update's norm?
    pub fn last_ingest_clipped(&self) -> bool {
        self.last_ingest_clipped
    }

    /// Per-row trimmed verdicts of the last server step, in ingest
    /// order (empty unless trimming is on and a step has run).
    pub fn last_trim_flags(&self) -> &[bool] {
        &self.last_trim_flags
    }

    /// Distance between the server model and the shared hidden state of
    /// family 0 — the "quantization" error term of Lemma F.9
    /// (‖x^t − x̂^t‖²).
    pub fn hidden_state_error_sq(&self) -> f64 {
        vecf::dist2_sq(&self.x, &self.families[0].x_hat)
    }

    /// Cumulative per-stage wall time of the aggregation pipeline.
    /// `steps` is always real; the ns fields are all-zero unless
    /// [`telemetry::set_enabled`] turned span capture on.
    pub fn stage_timings(&self) -> &StageTimings {
        &self.stages
    }

    /// Full server-state snapshot for a `Checkpoint` journal event:
    /// model, hidden state, momentum, aggregation buffer, counters, the
    /// quantizer RNG stream and the comm/staleness accounting. Vectors
    /// are hex-encoded little-endian f32 bytes and RNG words are hex
    /// strings, so [`Server::restore_state`] is a bit-exact round trip.
    /// Stage timings are wall-clock observer data and deliberately not
    /// part of the snapshot.
    pub fn state_json(&self) -> Json {
        let rng = self.rng.state();
        let mut fields = vec![
            ("d", Json::num(self.d as f64)),
            ("t", Json::num(self.t as f64)),
            ("k_filled", Json::num(self.k_filled as f64)),
            ("x", Json::str(&hex_f32s(&self.x))),
            ("x_hat", Json::str(&hex_f32s(&self.families[0].x_hat))),
            ("momentum", Json::str(&hex_f32s(&self.momentum))),
            ("buffer", Json::str(&hex_f32s(&self.buffer))),
            (
                "rng",
                Json::Arr(rng.iter().map(|&w| Json::str(&hex_u64(w))).collect()),
            ),
            ("uploads", Json::num(self.comm.uploads as f64)),
            ("upload_bytes", Json::num(self.comm.upload_bytes as f64)),
            ("broadcasts", Json::num(self.comm.broadcasts as f64)),
            ("broadcast_bytes", Json::num(self.comm.broadcast_bytes as f64)),
            ("staleness_max", Json::num(self.staleness_max as f64)),
            ("staleness_sum", Json::num(self.staleness_sum as f64)),
            ("staleness_n", Json::num(self.staleness_n as f64)),
        ];
        // Robust-aggregation state. Conditional so robust-off snapshots
        // stay byte-identical to the pre-robustness engine's — the
        // robust-off golden contract.
        if self.robust.enabled {
            fields.push(("clipped_updates", Json::num(self.clipped_updates as f64)));
            fields.push(("trimmed_updates", Json::num(self.trimmed_updates as f64)));
            if self.robust.trim_enabled() {
                // pending rows of a half-filled buffer (ingest order)
                fields.push((
                    "trim_rows",
                    Json::Arr(self.trim_rows.iter().map(|r| Json::str(&hex_f32s(r))).collect()),
                ));
            }
        }
        // Per-tier downlink families beyond the default. Conditional so
        // single-family snapshots stay byte-identical to the pre-family
        // engine's — the no-preset golden contract.
        if self.families.len() > 1 {
            fields.push((
                "x_hat_extra",
                Json::Arr(
                    self.families[1..]
                        .iter()
                        .map(|fam| Json::str(&hex_f32s(&fam.x_hat)))
                        .collect(),
                ),
            ));
        }
        Json::obj(fields)
    }

    /// Restore the snapshot taken by [`Server::state_json`] into a
    /// server built from the *same config* (codecs, K, shards and
    /// algorithm come from construction; only run state is restored).
    pub fn restore_state(&mut self, state: &Json) -> Result<()> {
        let field = |k: &str| {
            state
                .get(k)
                .ok_or_else(|| anyhow!("checkpoint state: missing field '{k}'"))
        };
        let uint = |k: &str| -> Result<u64> {
            field(k)?
                .as_f64()
                .map(|f| f as u64)
                .ok_or_else(|| anyhow!("checkpoint state: field '{k}' must be a number"))
        };
        let vector = |k: &str| -> Result<Vec<f32>> {
            let text = field(k)?
                .as_str()
                .ok_or_else(|| anyhow!("checkpoint state: field '{k}' must be a hex string"))?;
            let v = parse_hex_f32s(text)?;
            if v.len() != self.d {
                bail!(
                    "checkpoint state: '{k}' has dimension {} but the server has d={} — \
                     the checkpoint was taken under a different config",
                    v.len(),
                    self.d
                );
            }
            Ok(v)
        };
        let d = uint("d")? as usize;
        if d != self.d {
            bail!(
                "checkpoint state: snapshot dimension {d} != model dimension {} — \
                 the checkpoint was taken under a different config",
                self.d
            );
        }
        let rng_words = field("rng")?
            .as_arr()
            .ok_or_else(|| anyhow!("checkpoint state: 'rng' must be an array"))?;
        if rng_words.len() != 4 {
            bail!("checkpoint state: 'rng' must hold 4 words, got {}", rng_words.len());
        }
        let mut words = [0u64; 4];
        for (i, w) in rng_words.iter().enumerate() {
            let text = w
                .as_str()
                .ok_or_else(|| anyhow!("checkpoint state: rng words must be hex strings"))?;
            words[i] = parse_hex_u64(text)?;
        }
        self.x = vector("x")?;
        self.families[0].x_hat = Arc::new(vector("x_hat")?);
        match state.get("x_hat_extra") {
            None if self.families.len() > 1 => bail!(
                "checkpoint state: server has {} downlink families but the snapshot \
                 carries only the default x̂ — the checkpoint was taken under a \
                 different config",
                self.families.len()
            ),
            None => {}
            Some(v) => {
                let arr = v
                    .as_arr()
                    .ok_or_else(|| anyhow!("checkpoint state: 'x_hat_extra' must be an array"))?;
                if arr.len() != self.families.len().saturating_sub(1) {
                    bail!(
                        "checkpoint state: snapshot has {} extra downlink families but the \
                         server has {} — the checkpoint was taken under a different config",
                        arr.len(),
                        self.families.len().saturating_sub(1)
                    );
                }
                for (i, entry) in arr.iter().enumerate() {
                    let text = entry.as_str().ok_or_else(|| {
                        anyhow!("checkpoint state: 'x_hat_extra' entries must be hex strings")
                    })?;
                    let v = parse_hex_f32s(text)?;
                    if v.len() != self.d {
                        bail!(
                            "checkpoint state: 'x_hat_extra[{i}]' has dimension {} but the \
                             server has d={} — the checkpoint was taken under a different \
                             config",
                            v.len(),
                            self.d
                        );
                    }
                    self.families[i + 1].x_hat = Arc::new(v);
                }
            }
        }
        match state.get("clipped_updates") {
            None if self.robust.enabled => bail!(
                "checkpoint state: server has [fl.robust] enabled but the snapshot \
                 carries no robust counters — the checkpoint was taken under a \
                 different config"
            ),
            Some(_) if !self.robust.enabled => bail!(
                "checkpoint state: snapshot carries robust counters but [fl.robust] \
                 is disabled — the checkpoint was taken under a different config"
            ),
            None => {}
            Some(_) => {
                self.clipped_updates = uint("clipped_updates")?;
                self.trimmed_updates = uint("trimmed_updates")?;
            }
        }
        self.trim_spare.append(&mut self.trim_rows);
        match state.get("trim_rows") {
            None if self.robust.trim_enabled() => bail!(
                "checkpoint state: server trims its buffer but the snapshot carries \
                 no 'trim_rows' — the checkpoint was taken under a different config"
            ),
            Some(_) if !self.robust.trim_enabled() => bail!(
                "checkpoint state: snapshot carries 'trim_rows' but trimming is \
                 disabled — the checkpoint was taken under a different config"
            ),
            None => {}
            Some(v) => {
                let arr = v
                    .as_arr()
                    .ok_or_else(|| anyhow!("checkpoint state: 'trim_rows' must be an array"))?;
                for (i, entry) in arr.iter().enumerate() {
                    let text = entry.as_str().ok_or_else(|| {
                        anyhow!("checkpoint state: 'trim_rows' entries must be hex strings")
                    })?;
                    let row = parse_hex_f32s(text)?;
                    if row.len() != self.d {
                        bail!(
                            "checkpoint state: 'trim_rows[{i}]' has dimension {} but the \
                             server has d={} — the checkpoint was taken under a \
                             different config",
                            row.len(),
                            self.d
                        );
                    }
                    self.trim_rows.push(row);
                }
            }
        }
        self.momentum = vector("momentum")?;
        self.buffer = vector("buffer")?;
        self.k_filled = uint("k_filled")? as usize;
        self.t = uint("t")?;
        self.rng = Prng::from_state(words);
        self.comm.uploads = uint("uploads")?;
        self.comm.upload_bytes = uint("upload_bytes")?;
        self.comm.broadcasts = uint("broadcasts")?;
        self.comm.broadcast_bytes = uint("broadcast_bytes")?;
        self.staleness_max = uint("staleness_max")?;
        self.staleness_sum = uint("staleness_sum")?;
        self.staleness_n = uint("staleness_n")?;
        Ok(())
    }
}

/// The client-codec spec a server must decode with, per algorithm
/// (full-precision baselines always upload identity-coded deltas).
/// Shared with [`crate::coordinator::aggregator::EdgeAggregator`] so
/// every node of an aggregation tree resolves specs identically.
pub(crate) fn client_codec_spec(client_spec: &str, algorithm: Algorithm) -> String {
    match algorithm {
        Algorithm::Qafel | Algorithm::DirectQuant => client_spec.to_string(),
        Algorithm::FedBuff | Algorithm::FedAsync => "none".to_string(),
    }
}

/// The server-codec spec a downlink preset resolves to, per algorithm
/// (full-precision baselines always broadcast identity-coded state, so
/// every preset collapses onto family 0). Shared with the TCP leader so
/// negotiation resolves specs exactly like [`Server::new`] does.
pub(crate) fn server_codec_spec(server_spec: &str, algorithm: Algorithm) -> String {
    match algorithm {
        Algorithm::Qafel | Algorithm::DirectQuant => server_spec.to_string(),
        Algorithm::FedBuff | Algorithm::FedAsync => "none".to_string(),
    }
}

impl Server {
    /// Override the default client-upload codec (kept for callers that
    /// decode uploads produced under a different spec than
    /// `cfg.quant.client`; `Server::new` already attaches the config's
    /// codec).
    pub fn with_client_codec(mut self, spec: &str, algorithm: Algorithm) -> Result<Server> {
        self.client_codecs[0] = parse_spec(&client_codec_spec(spec, algorithm))?;
        Ok(self)
    }

    /// One-call constructor, equivalent to [`Server::new`] (kept for API
    /// compatibility from when `new` did not attach the client codec).
    pub fn build(cfg: &Config, x0: Vec<f32>, seed: u64) -> Result<Server> {
        Server::new(cfg, x0, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn cfg_with(algorithm: &str, k: usize) -> Config {
        let mut c = Config::default();
        c.fl.algorithm = Algorithm::parse(algorithm).unwrap();
        c.fl.buffer_size = k;
        c.fl.server_lr = 1.0;
        c.fl.server_momentum = 0.0;
        c
    }

    fn upload(server: &mut Server, x: &[f32], staleness: u64) -> ServerStep {
        let logic = crate::coordinator::ClientLogic::new(
            &cfg_for_logic(server), 1,
        ).unwrap();
        let msg = logic.quantize_delta_for_test(x);
        server.ingest(&msg, staleness).unwrap()
    }

    // helper: reconstruct a config whose client quantizer matches "none"
    fn cfg_for_logic(_server: &Server) -> Config {
        let mut c = Config::default();
        c.fl.algorithm = Algorithm::FedBuff;
        c
    }

    #[test]
    fn fedbuff_buffer_semantics() {
        let cfg = cfg_with("fedbuff", 3);
        let d = 4;
        let mut s = Server::build(&cfg, vec![0.0; d], 1).unwrap();
        // two updates: still buffered
        assert!(matches!(upload(&mut s, &[3.0, 0.0, 0.0, 0.0], 0), ServerStep::Buffered));
        assert!(matches!(upload(&mut s, &[0.0, 3.0, 0.0, 0.0], 0), ServerStep::Buffered));
        assert_eq!(s.t(), 0);
        // third fills the buffer: x += eta_g * mean
        let step = upload(&mut s, &[0.0, 0.0, 3.0, 0.0], 0);
        assert!(matches!(step, ServerStep::Stepped(_)));
        assert_eq!(s.t(), 1);
        assert_eq!(s.model(), &[1.0, 1.0, 1.0, 0.0]);
        // FedBuff: hidden state == model exactly (identity quantizer)
        assert_eq!(s.client_snapshot().as_slice(), &[1.0, 1.0, 1.0, 0.0]);
        assert_eq!(s.hidden_state_error_sq(), 0.0);
    }

    #[test]
    fn staleness_scaling_downweights() {
        let mut cfg = cfg_with("fedbuff", 1);
        cfg.fl.staleness_scaling = true;
        let mut s = Server::build(&cfg, vec![0.0; 1], 1).unwrap();
        upload(&mut s, &[1.0], 3); // w = 1/sqrt(4) = 0.5
        assert!((s.model()[0] - 0.5).abs() < 1e-6);
        assert_eq!(s.staleness_max, 3);
    }

    #[test]
    fn momentum_accumulates() {
        let mut cfg = cfg_with("fedbuff", 1);
        cfg.fl.server_momentum = 0.5;
        let mut s = Server::build(&cfg, vec![0.0; 1], 1).unwrap();
        upload(&mut s, &[1.0], 0); // v=1, x=1
        upload(&mut s, &[1.0], 0); // v=1.5, x=2.5
        assert!((s.model()[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn qafel_hidden_state_tracks_model_within_quant_error() {
        let mut cfg = cfg_with("qafel", 2);
        cfg.quant.client = "qsgd:8".into();
        cfg.quant.server = "qsgd:8".into();
        let d = 64;
        let mut s = Server::build(&cfg, vec![0.0; d], 2).unwrap();
        let mut rng = Prng::new(3);
        let qc = parse_spec("qsgd:8").unwrap();
        for round in 0..50 {
            let delta: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
            let msg = qc.quantize(&delta, &mut rng);
            let _ = s.ingest(&msg, round % 3).unwrap();
        }
        assert_eq!(s.t(), 25);
        let model_norm_sq: f64 = crate::util::vecf::norm2(s.model()).powi(2);
        // hidden state must stay close to the model (contraction of Q_s):
        assert!(
            s.hidden_state_error_sq() < model_norm_sq.max(1e-6),
            "err {} vs |x|^2 {}",
            s.hidden_state_error_sq(),
            model_norm_sq
        );
        // uploads/broadcast accounting
        assert_eq!(s.comm.uploads, 50);
        assert_eq!(s.comm.broadcasts, 25);
    }

    #[test]
    fn fedasync_forces_k1() {
        let cfg = cfg_with("fedasync", 10); // K in config ignored
        let mut s = Server::build(&cfg, vec![0.0; 2], 1).unwrap();
        assert_eq!(s.k_buffer(), 1);
        assert!(matches!(upload(&mut s, &[1.0, 0.0], 0), ServerStep::Stepped(_)));
    }

    #[test]
    fn directquant_broadcasts_absolute_model() {
        let mut cfg = cfg_with("directquant", 1);
        cfg.quant.client = "none".into();
        cfg.quant.server = "qsgd:4".into();
        let mut s = Server::build(&cfg, vec![0.0; 16], 1).unwrap();
        let qc = parse_spec("none").unwrap();
        let mut rng = Prng::new(9);
        let delta: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let msg = qc.quantize(&delta, &mut rng);
        match s.ingest(&msg, 0).unwrap() {
            ServerStep::Stepped(bs) => {
                assert_eq!(bs.len(), 1);
                assert!(bs[0].absolute);
                assert_eq!(bs[0].codec, 0);
            }
            _ => panic!("expected step"),
        }
        // snapshot is the *quantized* model, not the exact one
        let snap = s.client_snapshot();
        assert_ne!(snap.as_slice(), s.model());
    }

    #[test]
    fn new_attaches_client_codec_from_config() {
        // regression: Server::new used to hard-code quant_c = "none", so
        // forgetting with_client_codec silently decoded qsgd uploads as
        // raw f32 (or failed downstream with an unhelpful size error).
        let mut cfg = cfg_with("qafel", 1);
        cfg.quant.client = "qsgd:4".into();
        cfg.quant.server = "qsgd:4".into();
        let d = 256;
        let mut s = Server::new(&cfg, vec![0.0; d], 1).unwrap();
        let qc = parse_spec("qsgd:4").unwrap();
        let mut rng = Prng::new(4);
        let delta = vec![0.25f32; d];
        let msg = qc.quantize(&delta, &mut rng);
        assert!(matches!(s.ingest(&msg, 0).unwrap(), ServerStep::Stepped(_)));
        // the decoded mean lands near the true delta, proving the qsgd
        // codec (not identity) decoded the payload
        let mean = s.model().iter().sum::<f32>() / d as f32;
        assert!((mean - 0.25).abs() < 0.05, "decoded mean {mean}");
    }

    #[test]
    fn mismatched_upload_fails_loudly() {
        let mut cfg = cfg_with("qafel", 1);
        cfg.quant.client = "qsgd:4".into();
        let d = 256;
        let mut s = Server::new(&cfg, vec![0.0; d], 1).unwrap();
        // client "accidentally" sends full precision
        let full = parse_spec("none").unwrap();
        let mut rng = Prng::new(5);
        let msg = full.quantize(&vec![1.0f32; d], &mut rng);
        let err = s.ingest(&msg, 0).unwrap_err().to_string();
        assert!(err.contains("qsgd:4"), "unhelpful error: {err}");
        // truncated payload of the right codec also fails loudly
        let qc = parse_spec("qsgd:4").unwrap();
        let mut msg = qc.quantize(&vec![1.0f32; d], &mut rng);
        msg.payload.pop();
        assert!(s.ingest(&msg, 0).is_err());
        // wrong dimension is rejected before decode
        let msg = qc.quantize(&vec![1.0f32; d / 2], &mut rng);
        assert!(s.ingest(&msg, 0).is_err());
        // nothing was recorded for the rejected uploads
        assert_eq!(s.comm.uploads, 0);
    }

    #[test]
    fn heterogeneous_uploads_decode_with_their_own_codec() {
        let mut cfg = cfg_with("qafel", 2);
        cfg.quant.client = "none".into(); // codec 0: exact wire format
        cfg.quant.server = "none".into();
        let d = 256;
        let mut s = Server::new(&cfg, vec![0.0; d], 1).unwrap();
        let top = s.register_client_codec("top:0.25").unwrap();
        assert_eq!(top, 1);
        // dedup: the default spec and repeats map to existing ids
        assert_eq!(s.register_client_codec("none").unwrap(), 0);
        assert_eq!(s.register_client_codec("top:0.25").unwrap(), top);
        assert_eq!(s.num_client_codecs(), 2);
        assert_eq!(s.client_codec_name(top), "top:0.25");

        let q0 = parse_spec("none").unwrap();
        let q1 = parse_spec("top:0.25").unwrap();
        let mut rng = Prng::new(3);
        let delta: Vec<f32> = (0..d).map(|i| (i as f32 * 0.1).sin()).collect();
        let m0 = q0.quantize(&delta, &mut rng);
        let m1 = q1.quantize(&delta, &mut rng);
        assert_ne!(m0.wire_bytes(), m1.wire_bytes());
        // wrong codec id fails loudly before touching the buffer
        assert!(s.ingest_from(&m1, 0, 0).is_err());
        assert!(s.ingest_from(&m0, 0, 99).is_err());
        assert_eq!(s.comm.uploads, 0);
        // one full-precision and one top-k upload share the buffer
        assert!(matches!(s.ingest_from(&m0, 0, 0).unwrap(), ServerStep::Buffered));
        match s.ingest_from(&m1, 0, top).unwrap() {
            ServerStep::Stepped(_) => {}
            other => panic!("expected step, got {other:?}"),
        }
        // model == mean of the two decoded updates (momentum 0, eta 1),
        // computed with the same op order as the server step
        let mut buf = vec![0f32; d];
        q0.accumulate(&m0, 1.0, &mut buf).unwrap();
        q1.accumulate(&m1, 1.0, &mut buf).unwrap();
        let expect: Vec<f32> = buf.iter().map(|&b| b * 0.5).collect();
        assert_eq!(s.model(), &expect[..]);
        // per-message byte accounting used the real payload sizes
        assert_eq!(
            s.comm.upload_bytes,
            (m0.wire_bytes() + m1.wire_bytes()) as u64
        );
    }

    #[test]
    fn expected_bytes_routing_finds_unique_codec_and_rejects_ambiguity() {
        let mut cfg = cfg_with("qafel", 2);
        cfg.quant.client = "none".into();
        let d = 256;
        let mut s = Server::new(&cfg, vec![0.0; d], 1).unwrap();
        let top = s.register_client_codec("top:0.25").unwrap();
        let q1 = parse_spec("top:0.25").unwrap();
        assert_eq!(s.codec_for_bytes(q1.expected_bytes(d)).unwrap(), top);
        assert_eq!(s.codec_for_bytes(4 * d).unwrap(), 0);
        assert!(s.codec_for_bytes(1).is_err(), "no codec emits 1-byte payloads");
        // qsgd:8 and rand:0.25 both emit 264B at d=256: routing by size
        // must refuse to guess between them
        let a = s.register_client_codec("qsgd:8").unwrap();
        let b = s.register_client_codec("rand:0.25").unwrap();
        let bytes = parse_spec("qsgd:8").unwrap().expected_bytes(d);
        assert_eq!(bytes, parse_spec("rand:0.25").unwrap().expected_bytes(d));
        assert_ne!(a, b);
        let err = s.codec_for_bytes(bytes).unwrap_err().to_string();
        assert!(err.contains("ambiguous"), "{err}");
    }

    #[test]
    fn sharded_steps_bit_identical_across_shard_counts() {
        // The determinism contract of the sharded pipeline: model,
        // hidden state and broadcast bytes are identical for every S.
        let mut cfg = cfg_with("qafel", 2);
        cfg.quant.client = "qsgd:4".into();
        cfg.quant.server = "qsgd:4".into();
        cfg.fl.server_momentum = 0.3;
        let d = 3 * 128 + 57; // ragged tail
        let mk = |shards: usize| {
            let mut c = cfg.clone();
            c.fl.shards = shards;
            Server::build(&c, vec![0.0; d], 7).unwrap()
        };
        for shards in [2usize, 3, 8] {
            let mut reference = mk(1);
            let mut s = mk(shards);
            assert_eq!(s.shards(), shards);
            let qc = parse_spec("qsgd:4").unwrap();
            let mut rng_a = Prng::new(11);
            let mut rng_b = Prng::new(11);
            for round in 0..12u64 {
                let delta: Vec<f32> = (0..d).map(|i| ((i as f32) + round as f32).sin()).collect();
                let msg_a = qc.quantize(&delta, &mut rng_a);
                let msg_b = qc.quantize(&delta, &mut rng_b);
                let a = reference.ingest(&msg_a, round % 4).unwrap();
                let b = s.ingest(&msg_b, round % 4).unwrap();
                match (a, b) {
                    (ServerStep::Stepped(ba), ServerStep::Stepped(bb)) => {
                        assert_eq!(ba[0].msg.payload, bb[0].msg.payload, "S={shards} broadcast");
                    }
                    (ServerStep::Buffered, ServerStep::Buffered) => {}
                    _ => panic!("S={shards}: step/buffer divergence"),
                }
            }
            assert_eq!(reference.model(), s.model(), "S={shards} model");
            assert_eq!(
                reference.client_snapshot().as_slice(),
                s.client_snapshot().as_slice(),
                "S={shards} hidden state"
            );
        }
    }

    #[test]
    fn stage_timings_count_steps_without_telemetry() {
        // `steps` counts unconditionally (the ns fields gate on the
        // global telemetry switch, which other tests may toggle — so
        // only the counter is asserted here).
        let cfg = cfg_with("fedbuff", 1);
        let mut s = Server::build(&cfg, vec![0.0; 4], 1).unwrap();
        upload(&mut s, &[1.0, 0.0, 0.0, 0.0], 0);
        upload(&mut s, &[0.0, 1.0, 0.0, 0.0], 0);
        assert_eq!(s.stage_timings().steps, 2);
    }

    #[test]
    fn checkpoint_state_round_trips_bit_exactly() {
        let mut cfg = cfg_with("qafel", 2);
        cfg.quant.client = "qsgd:8".into();
        cfg.quant.server = "qsgd:4".into();
        cfg.fl.server_momentum = 0.3;
        let d = 128 + 17;
        let mut a = Server::build(&cfg, vec![0.0; d], 5).unwrap();
        let qc = parse_spec("qsgd:8").unwrap();
        let mut up = Prng::new(21);
        // 5 ingests = 2 steps + one buffered upload: the snapshot must
        // capture a half-filled aggregation buffer too
        for round in 0..5u64 {
            let delta: Vec<f32> =
                (0..d).map(|i| (i as f32 * 0.03 + round as f32).sin()).collect();
            let msg = qc.quantize(&delta, &mut up);
            let _ = a.ingest(&msg, round % 3).unwrap();
        }
        let snap = a.state_json();

        // restore into a fresh server of the same config; the different
        // construction seed must not matter (the snapshot carries the
        // live quantizer RNG state)
        let mut b = Server::build(&cfg, vec![0.0; d], 999).unwrap();
        b.restore_state(&snap).unwrap();
        assert_eq!(b.t(), a.t());
        assert_eq!(b.model(), a.model());
        assert_eq!(b.client_snapshot().as_slice(), a.client_snapshot().as_slice());
        assert_eq!(b.comm.uploads, a.comm.uploads);
        assert_eq!(b.staleness_mean(), a.staleness_mean());

        // both continue bit-identically, including quantizer noise draws
        let more: Vec<QuantizedMsg> = (0..6u64)
            .map(|r| {
                let delta: Vec<f32> =
                    (0..d).map(|i| (i as f32 * 0.07 + r as f32).cos()).collect();
                qc.quantize(&delta, &mut up)
            })
            .collect();
        for (r, msg) in more.iter().enumerate() {
            let ra = a.ingest(msg, (r % 2) as u64).unwrap();
            let rb = b.ingest(msg, (r % 2) as u64).unwrap();
            match (ra, rb) {
                (ServerStep::Stepped(x), ServerStep::Stepped(y)) => {
                    assert_eq!(x[0].t, y[0].t, "round {r}");
                    assert_eq!(x[0].msg.payload, y[0].msg.payload, "round {r} broadcast");
                }
                (ServerStep::Buffered, ServerStep::Buffered) => {}
                _ => panic!("restored server diverged at round {r}"),
            }
        }
        assert_eq!(a.model(), b.model());

        // a snapshot from a different model dimension fails loudly
        let mut tiny = Server::build(&cfg, vec![0.0; 8], 1).unwrap();
        let err = tiny.restore_state(&snap).unwrap_err().to_string();
        assert!(err.contains("different config"), "{err}");
        // and a gutted snapshot names the missing field
        let err = tiny.restore_state(&Json::obj(vec![])).unwrap_err().to_string();
        assert!(err.contains("missing field"), "{err}");
    }

    #[test]
    fn golden_broadcast_matches_prerefactor_reference() {
        // Replays the pre-refactor Algorithm 1 step with plain trait
        // calls (sequential accumulate, momentum loop, trait-level
        // quantize on the server rng stream) and asserts the sharded
        // server emits byte-identical broadcasts from the same inputs.
        let mut cfg = cfg_with("qafel", 2);
        cfg.quant.client = "qsgd:8".into();
        cfg.quant.server = "qsgd:4".into();
        cfg.fl.server_momentum = 0.3;
        cfg.fl.shards = 4;
        let d = 2 * 128 + 33;
        let seed = 42u64;
        let mut server = Server::build(&cfg, vec![0.0; d], seed).unwrap();

        // reference state, exactly as the pre-refactor server kept it
        let qc = parse_spec("qsgd:8").unwrap();
        let qs = parse_spec("qsgd:4").unwrap();
        let mut ref_rng = Prng::new(seed).stream("server-quant");
        let mut ref_x = vec![0.0f32; d];
        let mut ref_xh = vec![0.0f32; d];
        let mut ref_v = vec![0.0f32; d];
        let mut ref_buf = vec![0.0f32; d];

        let mut up_rng = Prng::new(9);
        for round in 0..10u64 {
            let delta: Vec<f32> = (0..d).map(|i| (i as f32 * 0.01 + round as f32).cos()).collect();
            let msg = qc.quantize(&delta, &mut up_rng);
            qc.accumulate(&msg, 1.0, &mut ref_buf).unwrap();
            let stepped = server.ingest(&msg, 0).unwrap();
            if (round + 1) % 2 != 0 {
                assert!(matches!(stepped, ServerStep::Buffered));
                continue;
            }
            // pre-refactor step
            for i in 0..d {
                ref_v[i] = 0.3 * ref_v[i] + ref_buf[i] * 0.5;
                ref_x[i] += ref_v[i];
            }
            crate::util::vecf::zero(&mut ref_buf);
            let mut diff = vec![0.0f32; d];
            crate::util::vecf::sub(&mut diff, &ref_x, &ref_xh);
            let ref_msg = qs.quantize(&diff, &mut ref_rng);
            qs.accumulate(&ref_msg, 1.0, &mut ref_xh).unwrap();
            match stepped {
                ServerStep::Stepped(b) => {
                    assert_eq!(b[0].msg.payload, ref_msg.payload, "round {round}");
                }
                ServerStep::Buffered => panic!("expected step at round {round}"),
            }
            assert_eq!(server.model(), &ref_x[..], "round {round} model");
            assert_eq!(server.client_snapshot().as_slice(), &ref_xh[..], "round {round} x_hat");
        }
    }

    #[test]
    fn downlink_families_broadcast_per_tier() {
        let mut cfg = cfg_with("qafel", 2);
        cfg.quant.client = "none".into();
        cfg.quant.server = "qsgd:8".into();
        let d = 256;
        let mut plain = Server::build(&cfg, vec![0.0; d], 7).unwrap();
        let mut multi = Server::build(&cfg, vec![0.0; d], 7).unwrap();
        // dedup: the default spec maps to family 0; a distinct preset
        // opens family 1; repeats return the existing id
        assert_eq!(multi.register_server_codec("qsgd:8").unwrap(), 0);
        let fam = multi.register_server_codec("qsgd:2").unwrap();
        assert_eq!(fam, 1);
        assert_eq!(multi.register_server_codec("qsgd:2").unwrap(), fam);
        assert_eq!(multi.num_server_codecs(), 2);
        assert_eq!(multi.server_codec_name(fam), "qsgd:2");
        assert!(multi.server_codec_bytes(fam) < multi.server_codec_bytes(0));

        let qc = parse_spec("none").unwrap();
        let mut rng_a = Prng::new(11);
        let mut rng_b = Prng::new(11);
        let mut steps = 0u32;
        for round in 0..8u64 {
            let delta: Vec<f32> =
                (0..d).map(|i| (i as f32 * 0.1 + round as f32).sin()).collect();
            let ma = qc.quantize(&delta, &mut rng_a);
            let mb = qc.quantize(&delta, &mut rng_b);
            match (plain.ingest(&ma, 0).unwrap(), multi.ingest(&mb, 0).unwrap()) {
                (ServerStep::Stepped(a), ServerStep::Stepped(b)) => {
                    steps += 1;
                    assert_eq!(a.len(), 1);
                    assert_eq!(b.len(), 2);
                    assert_eq!(b[0].codec, 0);
                    assert_eq!(b[1].codec, 1);
                    assert_eq!(b[0].t, b[1].t);
                    // the extra family draws AFTER family 0 on the shared
                    // stream, so the first step's family-0 bytes match the
                    // single-family server exactly
                    if steps == 1 {
                        assert_eq!(a[0].msg.payload, b[0].msg.payload);
                    }
                    assert_ne!(b[0].msg.payload, b[1].msg.payload);
                }
                (ServerStep::Buffered, ServerStep::Buffered) => {}
                _ => panic!("step/buffer divergence"),
            }
        }
        assert_eq!(steps, 4);
        // families touch only x̂ — the model itself is family-agnostic
        assert_eq!(plain.model(), multi.model());
        // each family holds its own hidden state
        assert_ne!(
            multi.family_snapshot(0).as_slice(),
            multi.family_snapshot(1).as_slice()
        );
        // broadcast accounting counts every family's bytes
        assert_eq!(multi.comm.broadcasts, 2 * plain.comm.broadcasts);
    }

    #[test]
    fn downlink_family_registration_locked_after_first_ingest() {
        let mut cfg = cfg_with("qafel", 2);
        cfg.quant.client = "none".into();
        cfg.quant.server = "qsgd:8".into();
        let d = 64;
        let mut s = Server::build(&cfg, vec![0.0; d], 1).unwrap();
        let qc = parse_spec("none").unwrap();
        let mut rng = Prng::new(2);
        let msg = qc.quantize(&vec![1.0f32; d], &mut rng);
        assert!(matches!(s.ingest(&msg, 0).unwrap(), ServerStep::Buffered));
        // dedup hits stay fine; a genuinely new family is rejected loudly
        assert_eq!(s.register_server_codec("qsgd:8").unwrap(), 0);
        let err = s.register_server_codec("qsgd:2").unwrap_err().to_string();
        assert!(err.contains("before the first ingest"), "{err}");
        // full-precision baselines resolve every preset onto family 0
        let fb = cfg_with("fedbuff", 1);
        let mut s = Server::build(&fb, vec![0.0; d], 1).unwrap();
        assert_eq!(s.register_server_codec("qsgd:2").unwrap(), 0);
        assert_eq!(s.num_server_codecs(), 1);
    }

    #[test]
    fn multi_family_checkpoint_round_trips_and_guards_config() {
        let mut cfg = cfg_with("qafel", 2);
        cfg.quant.client = "none".into();
        cfg.quant.server = "qsgd:8".into();
        let d = 128;
        let mut a = Server::build(&cfg, vec![0.0; d], 5).unwrap();
        a.register_server_codec("qsgd:2").unwrap();
        let qc = parse_spec("none").unwrap();
        let mut up = Prng::new(21);
        for round in 0..5u64 {
            let delta: Vec<f32> =
                (0..d).map(|i| (i as f32 * 0.03 + round as f32).sin()).collect();
            let msg = qc.quantize(&delta, &mut up);
            let _ = a.ingest(&msg, 0).unwrap();
        }
        let snap = a.state_json();
        assert!(snap.get("x_hat_extra").is_some());

        let mut b = Server::build(&cfg, vec![0.0; d], 999).unwrap();
        b.register_server_codec("qsgd:2").unwrap();
        b.restore_state(&snap).unwrap();
        assert_eq!(b.family_snapshot(1).as_slice(), a.family_snapshot(1).as_slice());
        // both continue bit-identically across every family
        for r in 0..4u64 {
            let delta: Vec<f32> =
                (0..d).map(|i| (i as f32 * 0.07 + r as f32).cos()).collect();
            let msg = qc.quantize(&delta, &mut up);
            match (a.ingest(&msg, 0).unwrap(), b.ingest(&msg, 0).unwrap()) {
                (ServerStep::Stepped(x), ServerStep::Stepped(y)) => {
                    assert_eq!(x.len(), y.len());
                    for (bx, by) in x.iter().zip(&y) {
                        assert_eq!(bx.msg.payload, by.msg.payload, "round {r}");
                    }
                }
                (ServerStep::Buffered, ServerStep::Buffered) => {}
                _ => panic!("restored multi-family server diverged at round {r}"),
            }
        }
        // a single-family server refuses a multi-family snapshot...
        let mut plain = Server::build(&cfg, vec![0.0; d], 1).unwrap();
        let err = plain.restore_state(&snap).unwrap_err().to_string();
        assert!(err.contains("different config"), "{err}");
        // ...and a multi-family server refuses a single-family snapshot
        let plain_snap = Server::build(&cfg, vec![0.0; d], 1).unwrap().state_json();
        assert!(plain_snap.get("x_hat_extra").is_none());
        let mut m = Server::build(&cfg, vec![0.0; d], 1).unwrap();
        m.register_server_codec("qsgd:2").unwrap();
        let err = m.restore_state(&plain_snap).unwrap_err().to_string();
        assert!(err.contains("different config"), "{err}");
    }

    #[test]
    fn robust_clip_bounds_update_norms() {
        let mut cfg = cfg_with("qafel", 2);
        cfg.quant.client = "none".into();
        cfg.quant.server = "none".into();
        cfg.fl.robust.enabled = true;
        cfg.fl.robust.clip_norm = 2.0;
        let d = 4;
        let mut s = Server::build(&cfg, vec![0.0; d], 1).unwrap();
        let qc = parse_spec("none").unwrap();
        let mut rng = Prng::new(3);
        // norm exactly at the bound passes untouched
        let m1 = qc.quantize(&[2.0, 0.0, 0.0, 0.0], &mut rng);
        assert!(matches!(s.ingest(&m1, 0).unwrap(), ServerStep::Buffered));
        assert!(!s.last_ingest_clipped());
        // norm 6 shrinks to 2: the oversized update cannot move the
        // model further than an honest clip-sized one
        let m2 = qc.quantize(&[6.0, 0.0, 0.0, 0.0], &mut rng);
        assert!(matches!(s.ingest(&m2, 0).unwrap(), ServerStep::Stepped(_)));
        assert!(s.last_ingest_clipped());
        assert_eq!(s.clipped_updates, 1);
        assert_eq!(s.model(), &[2.0, 0.0, 0.0, 0.0]);

        // normalize mode rescales *every* update to exactly clip_norm,
        // but only over-norm ones count as clipped
        let mut cfg = cfg.clone();
        cfg.fl.robust.normalize = true;
        let mut s = Server::build(&cfg, vec![0.0; d], 1).unwrap();
        let m1 = qc.quantize(&[1.0, 0.0, 0.0, 0.0], &mut rng);
        let m2 = qc.quantize(&[0.0, 8.0, 0.0, 0.0], &mut rng);
        let _ = s.ingest(&m1, 0).unwrap();
        let _ = s.ingest(&m2, 0).unwrap();
        assert_eq!(s.clipped_updates, 1);
        assert_eq!(s.model(), &[1.0, 1.0, 0.0, 0.0]); // both land at norm 2, /K
    }

    #[test]
    fn robust_trim_excludes_outlier_rows() {
        let mut cfg = cfg_with("qafel", 5);
        cfg.quant.client = "none".into();
        cfg.quant.server = "none".into();
        cfg.fl.robust.enabled = true;
        cfg.fl.robust.trim_frac = 0.2; // g = floor(0.2*5) = 1 per side
        let d = 4;
        let mut s = Server::build(&cfg, vec![0.0; d], 1).unwrap();
        let qc = parse_spec("none").unwrap();
        let mut rng = Prng::new(7);
        // honest rows are rotations of [1,2,3,4]: per coordinate the
        // honest values are {1,2,3,4}, so the per-coordinate trim drops
        // the adversary (lowest) and one honest 4 (highest), keeping
        // {1,2,3} -> mean 2. No honest row is excluded at a majority of
        // coordinates; the adversary is excluded at all of them.
        let honest = [
            [1.0f32, 2.0, 3.0, 4.0],
            [2.0, 3.0, 4.0, 1.0],
            [3.0, 4.0, 1.0, 2.0],
            [4.0, 1.0, 2.0, 3.0],
        ];
        for row in &honest {
            let m = qc.quantize(row, &mut rng);
            assert!(matches!(s.ingest(&m, 0).unwrap(), ServerStep::Buffered));
        }
        let adv = qc.quantize(&[-100.0, -100.0, -100.0, -100.0], &mut rng);
        assert!(matches!(s.ingest(&adv, 0).unwrap(), ServerStep::Stepped(_)));
        assert_eq!(s.model(), &[2.0, 2.0, 2.0, 2.0]);
        assert_eq!(s.trimmed_updates, 1);
        assert_eq!(s.last_trim_flags(), &[false, false, false, false, true]);
    }

    #[test]
    fn robust_sharded_bit_identical_across_shard_counts() {
        let mut cfg = cfg_with("qafel", 4);
        cfg.quant.client = "qsgd:4".into();
        cfg.quant.server = "qsgd:4".into();
        cfg.fl.server_momentum = 0.3;
        cfg.fl.staleness_scaling = true;
        cfg.fl.robust.enabled = true;
        cfg.fl.robust.clip_norm = 3.0;
        cfg.fl.robust.trim_frac = 0.25; // g = 1 of 4 rows per side
        let d = 3 * 128 + 57; // ragged tail
        let mk = |shards: usize| {
            let mut c = cfg.clone();
            c.fl.shards = shards;
            Server::build(&c, vec![0.0; d], 7).unwrap()
        };
        for shards in [2usize, 4, 8] {
            let mut reference = mk(1);
            let mut s = mk(shards);
            let qc = parse_spec("qsgd:4").unwrap();
            let mut rng_a = Prng::new(11);
            let mut rng_b = Prng::new(11);
            for round in 0..12u64 {
                let scale = if round % 3 == 0 { 40.0 } else { 1.0 }; // some rows oversized
                let delta: Vec<f32> = (0..d)
                    .map(|i| scale * ((i as f32) + round as f32).sin())
                    .collect();
                let msg_a = qc.quantize(&delta, &mut rng_a);
                let msg_b = qc.quantize(&delta, &mut rng_b);
                let a = reference.ingest(&msg_a, round % 4).unwrap();
                let b = s.ingest(&msg_b, round % 4).unwrap();
                match (a, b) {
                    (ServerStep::Stepped(ba), ServerStep::Stepped(bb)) => {
                        assert_eq!(ba[0].msg.payload, bb[0].msg.payload, "S={shards} broadcast");
                        assert_eq!(
                            reference.last_trim_flags(),
                            s.last_trim_flags(),
                            "S={shards} trim attribution"
                        );
                    }
                    (ServerStep::Buffered, ServerStep::Buffered) => {}
                    _ => panic!("S={shards}: step/buffer divergence"),
                }
            }
            assert_eq!(reference.model(), s.model(), "S={shards} model");
            assert_eq!(reference.clipped_updates, s.clipped_updates, "S={shards} clips");
            assert_eq!(reference.trimmed_updates, s.trimmed_updates, "S={shards} trims");
            assert!(reference.clipped_updates > 0 && reference.trimmed_updates > 0);
        }
    }

    #[test]
    fn robust_checkpoint_round_trips_and_guards_config() {
        let mut cfg = cfg_with("qafel", 3);
        cfg.quant.client = "qsgd:8".into();
        cfg.quant.server = "qsgd:4".into();
        cfg.fl.robust.enabled = true;
        cfg.fl.robust.clip_norm = 2.0;
        cfg.fl.robust.trim_frac = 0.34; // g = 1 of 3
        let d = 96;
        let mut a = Server::build(&cfg, vec![0.0; d], 5).unwrap();
        let qc = parse_spec("qsgd:8").unwrap();
        let mut up = Prng::new(21);
        // 5 ingests = 1 step + two pending trim rows in the snapshot
        for round in 0..5u64 {
            let delta: Vec<f32> =
                (0..d).map(|i| (i as f32 * 0.05 + round as f32).sin()).collect();
            let msg = qc.quantize(&delta, &mut up);
            let _ = a.ingest(&msg, round % 2).unwrap();
        }
        let snap = a.state_json();
        assert!(snap.get("clipped_updates").is_some());
        assert_eq!(snap.get("trim_rows").unwrap().as_arr().unwrap().len(), 2);

        let mut b = Server::build(&cfg, vec![0.0; d], 999).unwrap();
        b.restore_state(&snap).unwrap();
        assert_eq!(b.clipped_updates, a.clipped_updates);
        assert_eq!(b.trimmed_updates, a.trimmed_updates);
        // both continue bit-identically through the next trimmed step
        for r in 0..4u64 {
            let delta: Vec<f32> =
                (0..d).map(|i| (i as f32 * 0.09 + r as f32).cos()).collect();
            let msg = qc.quantize(&delta, &mut up);
            match (a.ingest(&msg, 0).unwrap(), b.ingest(&msg, 0).unwrap()) {
                (ServerStep::Stepped(x), ServerStep::Stepped(y)) => {
                    assert_eq!(x[0].msg.payload, y[0].msg.payload, "round {r}");
                }
                (ServerStep::Buffered, ServerStep::Buffered) => {}
                _ => panic!("restored robust server diverged at round {r}"),
            }
        }
        assert_eq!(a.model(), b.model());

        // robust-off snapshots carry no robust fields at all...
        let plain_cfg = cfg_with("qafel", 3);
        let plain_snap = Server::build(&plain_cfg, vec![0.0; d], 1).unwrap().state_json();
        assert!(plain_snap.get("clipped_updates").is_none());
        assert!(plain_snap.get("trim_rows").is_none());
        // ...and config mismatches are refused in both directions
        let mut robust = Server::build(&cfg, vec![0.0; d], 1).unwrap();
        let err = robust.restore_state(&plain_snap).unwrap_err().to_string();
        assert!(err.contains("different config"), "{err}");
        let mut plain = Server::build(&plain_cfg, vec![0.0; d], 1).unwrap();
        let err = plain.restore_state(&snap).unwrap_err().to_string();
        assert!(err.contains("different config"), "{err}");
    }

    #[test]
    fn trim_rejects_partial_aggregates() {
        let mut cfg = cfg_with("qafel", 2);
        cfg.quant.client = "none".into();
        cfg.fl.robust.enabled = true;
        cfg.fl.robust.trim_frac = 0.2;
        let d = 8;
        let mut s = Server::build(&cfg, vec![0.0; d], 1).unwrap();
        let p = s.register_partial_codec("none").unwrap();
        let qc = parse_spec("none").unwrap();
        let mut rng = Prng::new(2);
        let msg = qc.quantize(&vec![1.0f32; d], &mut rng);
        let hist = crate::scenario::metrics::StalenessHist::default();
        let err = s.ingest_partial(&msg, 2, &hist, p).unwrap_err().to_string();
        assert!(err.contains("trim"), "{err}");
    }
}
