//! The paper's coordination contribution (L3): an asynchronous FL server
//! with **buffered aggregation** and **bidirectional quantized
//! communication** through a shared **hidden state**.
//!
//! * [`server::Server`] — Algorithm 1 (QAFeL-server): ingest quantized
//!   client updates into a size-K buffer; on fill, take a momentum server
//!   step, quantize the hidden-state increment with `Q_s`, broadcast it,
//!   and advance the shared hidden state.
//! * [`client::ClientLogic`] — Algorithms 2 & 3 (QAFeL-client +
//!   background): copy the hidden state, run P local SGD steps (via a
//!   [`crate::runtime::Backend`]), quantize the delta with `Q_c`.
//! * Baselines fall out of the same machinery (DESIGN.md S3–S5):
//!   **FedBuff** = identity quantizers; **FedAsync** = K = 1;
//!   **DirectQuant** = broadcast `Q_s(x^{t+1})` with *no* hidden state —
//!   the error-propagating scheme the hidden state exists to avoid.
//! * [`aggregator`] — the composable [`aggregator::Aggregator`] seam:
//!   [`aggregator::EdgeAggregator`] nodes buffer a population slice and
//!   forward count-weighted quantized partials upstream; the root
//!   [`server::Server`] ingests them via `ingest_partial`. A trivial
//!   tree replays bit-identical to the flat server.

pub mod aggregator;
pub mod client;
pub mod hidden;
pub mod server;

pub use aggregator::{AggOutcome, Aggregator, EdgeAggregator, PartialAggregate};
pub use client::ClientLogic;
pub use hidden::{CatchUp, UpdateLog};
pub use server::{Broadcast, Server, ServerStep};
