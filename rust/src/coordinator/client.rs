//! Algorithms 2 & 3 — QAFeL-client and its background hidden-state
//! replica.
//!
//! [`ClientLogic`] is the client-side policy shared by the virtual-time
//! simulator (`sim/`) and the real networked runtime (`net/`):
//!
//! 1. copy the hidden state `y_0 <- x̂^t` (snapshot at *start* of local
//!    training — availability guaranteed by the background replica),
//! 2. run P local SGD steps through a [`Backend`],
//! 3. quantize the delta with the client quantizer `Q_c` and upload.
//!
//! [`HiddenReplica`] is Algorithm 3: a client-resident copy of the hidden
//! state advanced by every broadcast `q^t` — used in net mode where each
//! client owns a physical replica (the simulator shares the server's Arc
//! instead, which is behaviourally identical under reliable broadcast).

use crate::config::{Algorithm, Config};
use crate::coordinator::server::Broadcast;
use crate::quant::{parse_spec, sharded, QuantizedMsg, Quantizer};
use crate::runtime::Backend;
use crate::util::pool::ShardPool;
use crate::util::prng::Prng;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Client-side policy: local training + upload quantization.
///
/// Holds a small registry of client codecs: id 0 is the config default
/// (`quant.client`, resolved per algorithm) and further ids are
/// registered by [`ClientLogic::register_codec`] for per-tier quantizer
/// presets (`scenario.tiers.<name>.quant_client`, DESIGN_SCENARIOS.md).
/// All codecs share one quantizer-noise stream, so a single-codec run
/// draws exactly what it always did.
pub struct ClientLogic {
    codecs: Vec<Box<dyn Quantizer>>,
    algorithm: Algorithm,
    client_lr: f32,
    /// l2 clip applied to the delta before quantization (0 = off).
    clip_norm: f32,
    rng: std::cell::RefCell<Prng>,
}

/// A finished local round ready to send.
#[derive(Clone, Debug)]
pub struct Upload {
    pub msg: QuantizedMsg,
    pub train_loss: f32,
    pub train_acc: f32,
}

impl ClientLogic {
    pub fn new(cfg: &Config, seed: u64) -> Result<ClientLogic> {
        let spec = match cfg.fl.algorithm {
            Algorithm::Qafel | Algorithm::DirectQuant => cfg.quant.client.clone(),
            Algorithm::FedBuff | Algorithm::FedAsync => "none".to_string(),
        };
        Ok(ClientLogic {
            codecs: vec![parse_spec(&spec)?],
            algorithm: cfg.fl.algorithm,
            client_lr: cfg.fl.client_lr,
            clip_norm: cfg.fl.clip_norm,
            rng: std::cell::RefCell::new(Prng::new(seed).stream("client-quant")),
        })
    }

    /// Register an extra upload codec (a per-tier preset) and return its
    /// id for [`ClientLogic::run_round_with`]. The spec is resolved per
    /// algorithm exactly like `quant.client` (full-precision baselines
    /// ignore presets), and identical resolved codecs are deduplicated —
    /// registering the default spec returns 0.
    pub fn register_codec(&mut self, spec: &str) -> Result<usize> {
        let resolved = match self.algorithm {
            Algorithm::Qafel | Algorithm::DirectQuant => spec.to_string(),
            Algorithm::FedBuff | Algorithm::FedAsync => "none".to_string(),
        };
        let codec = parse_spec(&resolved)?;
        if let Some(i) = self.codecs.iter().position(|c| c.name() == codec.name()) {
            return Ok(i);
        }
        self.codecs.push(codec);
        Ok(self.codecs.len() - 1)
    }

    /// Algorithm 2 for one client trip: P local steps from `snapshot`,
    /// then quantize the delta. `round_seed` must be unique per upload.
    pub fn run_round(
        &self,
        backend: &dyn Backend,
        snapshot: &[f32],
        user: usize,
        round_seed: u64,
    ) -> Result<Upload> {
        self.run_round_with(backend, snapshot, user, round_seed, 0, 1.0)
    }

    /// [`ClientLogic::run_round`] with an explicit upload codec and a
    /// partial-work scale: a client that dropped after `m` of its `P`
    /// local steps submits `(m/P) * delta` (the linearized prefix of its
    /// local trajectory, FedBuff-style partial work), clipped and
    /// quantized like any other update. `scale = 1.0` is a full round
    /// and multiplies nothing — codec 0 at scale 1 is bit-identical to
    /// [`ClientLogic::run_round`].
    pub fn run_round_with(
        &self,
        backend: &dyn Backend,
        snapshot: &[f32],
        user: usize,
        round_seed: u64,
        codec: usize,
        scale: f32,
    ) -> Result<Upload> {
        self.run_round_transformed(backend, snapshot, user, round_seed, codec, scale, None)
    }

    /// [`ClientLogic::run_round_with`] plus an upload-time transform: the
    /// hostile-population hook (heavy-tailed gradient noise, adversarial
    /// rewrites — `scenario/robust.rs`). The transform sees the final
    /// honest delta — after partial-work scaling and client-side clipping
    /// — and whatever it leaves behind is quantized and shipped, exactly
    /// what a malicious client controls in the real protocol. `None` is
    /// the honest path, bit-identical to [`ClientLogic::run_round_with`].
    pub fn run_round_transformed(
        &self,
        backend: &dyn Backend,
        snapshot: &[f32],
        user: usize,
        round_seed: u64,
        codec: usize,
        scale: f32,
        transform: Option<&mut dyn FnMut(&mut [f32])>,
    ) -> Result<Upload> {
        let quant_c = self
            .codecs
            .get(codec)
            .ok_or_else(|| anyhow::anyhow!("client: unknown codec id {codec}"))?;
        let mut out = backend.client_round(snapshot, user, round_seed, self.client_lr)?;
        if scale != 1.0 {
            crate::util::vecf::scale(&mut out.delta, scale);
        }
        // FLSim-style update clipping: keeps a single diverging client (or
        // a staleness-amplified momentum loop) from poisoning the buffer.
        if self.clip_norm > 0.0 {
            let norm = crate::util::vecf::norm2(&out.delta) as f32;
            if norm > self.clip_norm {
                crate::util::vecf::scale(&mut out.delta, self.clip_norm / norm);
            }
        }
        if let Some(t) = transform {
            t(&mut out.delta);
        }
        let msg = quant_c.quantize(&out.delta, &mut self.rng.borrow_mut());
        Ok(Upload { msg, train_loss: out.loss, train_acc: out.acc })
    }

    /// Expected upload size for dimension d (for capacity planning).
    pub fn upload_bytes(&self, d: usize) -> usize {
        self.codecs[0].expected_bytes(d)
    }

    /// Expected upload size for a registered codec id.
    pub fn upload_bytes_for(&self, codec: usize, d: usize) -> usize {
        self.codecs[codec].expected_bytes(d)
    }

    pub fn quantizer_name(&self) -> String {
        self.codecs[0].name()
    }

    /// Spec name of a registered codec id.
    pub fn codec_name(&self, codec: usize) -> String {
        self.codecs[codec].name()
    }

    /// Number of registered upload codecs (>= 1; id 0 is the default).
    pub fn num_codecs(&self) -> usize {
        self.codecs.len()
    }

    /// Quantizer-noise stream state (for checkpoints).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.borrow().state()
    }

    /// Restore a [`ClientLogic::rng_state`] dump (the resume path).
    pub fn restore_rng(&mut self, state: [u64; 4]) {
        *self.rng.borrow_mut() = Prng::from_state(state);
    }

    /// Test helper: quantize an explicit delta (bypasses the backend).
    pub fn quantize_delta_for_test(&self, delta: &[f32]) -> QuantizedMsg {
        self.codecs[0].quantize(delta, &mut self.rng.borrow_mut())
    }
}

/// Algorithm 3 — the background process that keeps a client-resident
/// hidden-state replica in sync by applying every broadcast `q^t`.
pub struct HiddenReplica {
    x_hat: Vec<f32>,
    /// Server step the replica has caught up to.
    pub t: u64,
    quant_s: Box<dyn Quantizer>,
    /// Persistent decode pool (mirrors `cfg.fl.shards`): applying a
    /// broadcast is the same per-coordinate work as the server's x̂
    /// advance, so big replicas use the same shard-parallel decode path
    /// — on long-lived workers, not per-broadcast spawns.
    pool: Arc<ShardPool>,
}

impl HiddenReplica {
    /// Initialize from the pre-agreed x^0 (Algorithm 3 line 1), with a
    /// decode pool sized by `cfg.fl.shards`.
    pub fn new(cfg: &Config, x0: Vec<f32>) -> Result<HiddenReplica> {
        let pool = ShardPool::new(cfg.fl.shards.max(1));
        Self::with_pool(cfg, x0, pool)
    }

    /// Like [`HiddenReplica::new`] but sharing an existing pool (e.g.
    /// the owning server's) instead of spawning new workers.
    pub fn with_pool(cfg: &Config, x0: Vec<f32>, pool: Arc<ShardPool>) -> Result<HiddenReplica> {
        let spec = match cfg.fl.algorithm {
            Algorithm::Qafel | Algorithm::DirectQuant => cfg.quant.server.clone(),
            Algorithm::FedBuff | Algorithm::FedAsync => "none".to_string(),
        };
        Self::with_spec(&spec, x0, pool)
    }

    /// Build from an already-resolved server-codec spec — the per-tier
    /// downlink path, where a TCP worker decodes with the codec its tier
    /// negotiated in `JoinV2` rather than the config default.
    pub fn with_spec(spec: &str, x0: Vec<f32>, pool: Arc<ShardPool>) -> Result<HiddenReplica> {
        Ok(HiddenReplica { x_hat: x0, t: 0, quant_s: parse_spec(spec)?, pool })
    }

    /// Apply one broadcast (Algorithm 3 line 4). Incremental broadcasts
    /// must be applied in order — the hidden state is a running sum. An
    /// *absolute* broadcast (DirectQuant) carries the whole quantized
    /// model, so any forward jump is valid — load-bearing under budgeted
    /// fan-out, where a slow link may legitimately skip absolute frames.
    pub fn apply(&mut self, b: &Broadcast) -> Result<()> {
        if b.absolute {
            if b.t <= self.t {
                bail!("hidden replica: stale absolute broadcast t={} while at t={}", b.t, self.t);
            }
            sharded::dequantize_into(self.quant_s.as_ref(), &b.msg, &mut self.x_hat, &self.pool)?;
        } else {
            if b.t != self.t + 1 {
                bail!("hidden replica: got broadcast t={} while at t={}", b.t, self.t);
            }
            sharded::accumulate(self.quant_s.as_ref(), &b.msg, 1.0, &mut self.x_hat, &self.pool)?;
        }
        self.t = b.t;
        Ok(())
    }

    /// Re-base the replica on a full hidden state shipped by the server
    /// (Appendix B.1's full-state catch-up — the budgeted fan-out path
    /// when a worker fell further behind than the server's update log).
    pub fn resync(&mut self, t: u64, x_hat: Vec<f32>) -> Result<()> {
        if x_hat.len() != self.x_hat.len() {
            bail!(
                "hidden replica: full-state sync has dimension {} but the replica has {}",
                x_hat.len(),
                self.x_hat.len()
            );
        }
        if t < self.t {
            bail!("hidden replica: full-state sync t={} behind replica t={}", t, self.t);
        }
        self.x_hat = x_hat;
        self.t = t;
        Ok(())
    }

    pub fn state(&self) -> &[f32] {
        &self.x_hat
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::server::{Server, ServerStep};
    use crate::runtime::QuadraticBackend;

    fn qafel_cfg() -> Config {
        let mut c = Config::default();
        c.quant.client = "qsgd:8".into();
        c.quant.server = "qsgd:8".into();
        c.fl.buffer_size = 2;
        c.fl.server_lr = 1.0;
        c.fl.server_momentum = 0.0;
        c.fl.client_lr = 0.1;
        c.fl.clip_norm = 0.0;
        c
    }

    #[test]
    fn client_replica_stays_identical_to_server_hidden_state() {
        // The paper's core invariant: server and every client hold the
        // SAME hidden state after each broadcast, because both apply the
        // same quantized increment q^t.
        let cfg = qafel_cfg();
        let d = 32;
        let backend = QuadraticBackend::new(d, 4, 1.0, 0.1, 0.3, 0.05, 1, 5);
        let x0 = backend.init_params(0).unwrap();
        let mut server = Server::build(&cfg, x0.clone(), 1).unwrap();
        let logic = ClientLogic::new(&cfg, 2).unwrap();
        let mut replica = HiddenReplica::new(&cfg, x0).unwrap();

        for round in 0..20u64 {
            let snap = server.client_snapshot();
            let up = logic.run_round(&backend, &snap, (round % 4) as usize, round).unwrap();
            if let ServerStep::Stepped(b) = server.ingest(&up.msg, 0).unwrap() {
                replica.apply(&b[0]).unwrap();
                // bit-identical replicas
                assert_eq!(replica.state(), server.client_snapshot().as_slice(),
                           "divergence at t={}", b[0].t);
            }
        }
        assert_eq!(replica.t, 10);
    }

    #[test]
    fn out_of_order_broadcast_rejected() {
        let cfg = qafel_cfg();
        let mut replica = HiddenReplica::new(&cfg, vec![0.0; 8]).unwrap();
        let fake = Broadcast {
            t: 3,
            bytes: 0,
            msg: QuantizedMsg { payload: vec![], d: 8 },
            absolute: false,
            codec: 0,
        };
        assert!(replica.apply(&fake).is_err());
        // an absolute broadcast may jump forward (whole-model payload)
        // but never backward
        let mut cfg = qafel_cfg();
        cfg.fl.algorithm = Algorithm::DirectQuant;
        cfg.quant.server = "none".into();
        let mut replica = HiddenReplica::new(&cfg, vec![0.0; 2]).unwrap();
        let mut payload = Vec::new();
        payload.extend_from_slice(&7f32.to_le_bytes());
        payload.extend_from_slice(&8f32.to_le_bytes());
        let jump = Broadcast {
            t: 5,
            bytes: 8,
            msg: QuantizedMsg { payload, d: 2 },
            absolute: true,
            codec: 0,
        };
        replica.apply(&jump).unwrap();
        assert_eq!(replica.t, 5);
        assert_eq!(replica.state(), &[7.0, 8.0]);
        assert!(replica.apply(&jump).is_err(), "stale absolute must be rejected");
        // full-state resync re-bases the replica
        replica.resync(9, vec![1.0, 2.0]).unwrap();
        assert_eq!(replica.t, 9);
        assert_eq!(replica.state(), &[1.0, 2.0]);
        assert!(replica.resync(3, vec![0.0, 0.0]).is_err());
        assert!(replica.resync(10, vec![0.0]).is_err());
    }

    #[test]
    fn fedbuff_clients_upload_full_precision() {
        let mut cfg = qafel_cfg();
        cfg.fl.algorithm = Algorithm::FedBuff;
        let logic = ClientLogic::new(&cfg, 1).unwrap();
        assert_eq!(logic.quantizer_name(), "none");
        assert_eq!(logic.upload_bytes(29_474), 117_896);
    }

    #[test]
    fn qafel_upload_is_compressed() {
        let cfg = qafel_cfg();
        let logic = ClientLogic::new(&cfg, 1).unwrap();
        // 8-bit bucketed qsgd: 1 byte per coordinate + one f32 norm per
        // 128-coordinate bucket
        let d = 29_474usize;
        assert_eq!(logic.upload_bytes(d), 4 * d.div_ceil(128) + d);
    }

    #[test]
    fn codec_registry_dedups_and_respects_algorithm() {
        let cfg = qafel_cfg(); // quant.client = qsgd:8
        let mut logic = ClientLogic::new(&cfg, 1).unwrap();
        assert_eq!(logic.num_codecs(), 1);
        // registering the default spec dedups to id 0
        assert_eq!(logic.register_codec("qsgd:8").unwrap(), 0);
        let top = logic.register_codec("top:0.25").unwrap();
        assert_eq!(top, 1);
        assert_eq!(logic.codec_name(top), "top:0.25");
        // re-registering the same preset returns the same id
        assert_eq!(logic.register_codec("top:0.25").unwrap(), top);
        assert!(logic.upload_bytes_for(top, 1024) < logic.upload_bytes(1024));
        // full-precision baselines resolve every preset to identity
        let mut fb = qafel_cfg();
        fb.fl.algorithm = Algorithm::FedBuff;
        let mut logic = ClientLogic::new(&fb, 1).unwrap();
        assert_eq!(logic.register_codec("top:0.25").unwrap(), 0);
        assert_eq!(logic.num_codecs(), 1);
        // bad specs fail loudly
        assert!(ClientLogic::new(&qafel_cfg(), 1).unwrap().register_codec("huff:3").is_err());
    }

    #[test]
    fn rng_state_roundtrip_replays_quantizer_noise() {
        let cfg = qafel_cfg();
        let a = ClientLogic::new(&cfg, 4).unwrap();
        let delta = vec![0.37f32; 64];
        let _ = a.quantize_delta_for_test(&delta);
        let saved = a.rng_state();
        let next = a.quantize_delta_for_test(&delta);
        // a logic built with a different seed lands on the same stream
        // once the state is restored
        let mut b = ClientLogic::new(&cfg, 5).unwrap();
        b.restore_rng(saved);
        assert_eq!(b.quantize_delta_for_test(&delta).payload, next.payload);
    }

    #[test]
    fn partial_scale_shrinks_the_uploaded_delta() {
        let mut cfg = qafel_cfg();
        cfg.quant.client = "none".into(); // exact wire format: easy to decode
        let d = 32;
        let backend = QuadraticBackend::new(d, 4, 1.0, 0.1, 0.3, 0.05, 2, 5);
        let x0 = backend.init_params(0).unwrap();
        let logic = ClientLogic::new(&cfg, 2).unwrap();
        let full = logic.run_round_with(&backend, &x0, 0, 7, 0, 1.0).unwrap();
        let half = logic.run_round_with(&backend, &x0, 0, 7, 0, 0.5).unwrap();
        let qc = crate::quant::parse_spec("none").unwrap();
        let df = qc.dequantize(&full.msg).unwrap();
        let dh = qc.dequantize(&half.msg).unwrap();
        for i in 0..d {
            assert!((dh[i] - 0.5 * df[i]).abs() < 1e-6, "coord {i}: {} vs {}", dh[i], df[i]);
        }
        // scale 1.0 through run_round_with == run_round (same draws)
        let a = ClientLogic::new(&cfg, 9).unwrap();
        let b = ClientLogic::new(&cfg, 9).unwrap();
        let ra = a.run_round(&backend, &x0, 1, 3).unwrap();
        let rb = b.run_round_with(&backend, &x0, 1, 3, 0, 1.0).unwrap();
        assert_eq!(ra.msg.payload, rb.msg.payload);
        // unknown codec id is rejected
        assert!(a.run_round_with(&backend, &x0, 1, 3, 5, 1.0).is_err());
    }

    #[test]
    fn upload_transform_rewrites_the_outgoing_delta() {
        let mut cfg = qafel_cfg();
        cfg.quant.client = "none".into(); // exact wire format: easy to decode
        let d = 16;
        let backend = QuadraticBackend::new(d, 4, 1.0, 0.1, 0.3, 0.05, 2, 5);
        let x0 = backend.init_params(0).unwrap();
        let logic = ClientLogic::new(&cfg, 2).unwrap();
        let honest = logic.run_round_with(&backend, &x0, 0, 7, 0, 1.0).unwrap();
        let mut flip = |delta: &mut [f32]| {
            for x in delta.iter_mut() {
                *x = -*x;
            }
        };
        let hostile = logic
            .run_round_transformed(&backend, &x0, 0, 7, 0, 1.0, Some(&mut flip))
            .unwrap();
        let qc = crate::quant::parse_spec("none").unwrap();
        let dh = qc.dequantize(&honest.msg).unwrap();
        let da = qc.dequantize(&hostile.msg).unwrap();
        for i in 0..d {
            assert_eq!(da[i], -dh[i], "coord {i}");
        }
        // the transform runs after client-side clipping: a clip-bounded
        // honest delta is what the adversary gets to rewrite
        let mut clipped_cfg = cfg.clone();
        clipped_cfg.fl.clip_norm = 1e-3;
        let clipped = ClientLogic::new(&clipped_cfg, 2).unwrap();
        let up = clipped
            .run_round_transformed(&backend, &x0, 0, 7, 0, 1.0, Some(&mut flip))
            .unwrap();
        let dc = qc.dequantize(&up.msg).unwrap();
        let norm: f64 = dc.iter().map(|&x| f64::from(x) * f64::from(x)).sum::<f64>();
        assert!(norm.sqrt() <= 1e-3 + 1e-6, "transform saw unclipped delta");
    }

    #[test]
    fn training_actually_descends_through_the_full_loop() {
        let mut cfg = qafel_cfg();
        cfg.fl.client_lr = 0.2;
        let d = 16;
        let backend = QuadraticBackend::new(d, 4, 1.0, 0.5, 0.2, 0.01, 2, 9);
        let x0 = backend.init_params(0).unwrap();
        let g0 = backend.grad_norm_sq(&x0);
        let mut server = Server::build(&cfg, x0, 1).unwrap();
        let logic = ClientLogic::new(&cfg, 2).unwrap();
        for round in 0..600u64 {
            let snap = server.client_snapshot();
            let up = logic
                .run_round(&backend, &snap, (round % 4) as usize, round)
                .unwrap();
            let _ = server.ingest(&up.msg, 0).unwrap();
        }
        let g1 = backend.grad_norm_sq(server.model());
        assert!(g1 < g0 * 0.05, "{g0} -> {g1}");
    }
}
