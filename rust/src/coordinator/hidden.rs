//! Appendix B.1 — the **non-broadcast variant** of QAFeL.
//!
//! Networks without broadcast capability replace the per-step broadcast
//! with per-client catch-up on demand: the server keeps the last `C_max`
//! hidden-state increments, where `C_max = (model bytes) / (expected
//! increment bytes)`. When it samples a client whose replica is `s` steps
//! stale it sends either the `s` missed increments (if `s <= C_max`) or
//! the full current hidden state. Either way the cost is bounded by one
//! full-precision model, so "the communication cost of QAFeL is less
//! than or equal to that of FedBuff" (B.1).

use crate::coordinator::server::Broadcast;
use crate::quant::{sharded, Quantizer};
use crate::util::pool::ShardPool;
use anyhow::{bail, Result};
use std::collections::VecDeque;

/// What the server sends a catching-up client.
#[derive(Clone, Debug)]
pub enum CatchUp {
    /// The increments from `from_t + 1 ..= now` (applied in order).
    Increments(Vec<Broadcast>),
    /// Replica too stale: ship the whole hidden state.
    FullState { t: u64, x_hat: Vec<f32>, bytes: usize },
}

impl CatchUp {
    /// Wire bytes of this catch-up response.
    pub fn bytes(&self) -> usize {
        match self {
            CatchUp::Increments(v) => v.iter().map(|b| b.bytes).sum(),
            CatchUp::FullState { bytes, .. } => *bytes,
        }
    }
}

/// Server-side log of recent hidden-state increments.
pub struct UpdateLog {
    log: VecDeque<Broadcast>,
    /// Maximum retained increments (B.1's C_max).
    c_max: usize,
    /// Current hidden state (so full-state responses are available).
    x_hat: Vec<f32>,
    /// Step of the newest entry.
    t: u64,
    /// Bytes-sent accounting for the unicast downlink.
    pub bytes_sent: u64,
    pub full_syncs: u64,
    pub incremental_syncs: u64,
}

impl UpdateLog {
    /// `increment_bytes` is the expected size of one `Q_s` message **for
    /// this log's own codec**; C_max follows B.1's storage rule. With
    /// per-tier downlink codecs every family keeps its own log, and each
    /// must be sized from its own codec's wire size
    /// ([`crate::coordinator::Server::server_codec_bytes`]) — sizing a
    /// cheap-codec tier's log from the default codec evicts history at
    /// the wrong horizon and forces spurious full-state syncs.
    pub fn new(x0: Vec<f32>, increment_bytes: usize) -> UpdateLog {
        let model_bytes = x0.len() * 4;
        let c_max = (model_bytes / increment_bytes.max(1)).max(1);
        UpdateLog {
            log: VecDeque::with_capacity(c_max),
            c_max,
            x_hat: x0,
            t: 0,
            bytes_sent: 0,
            full_syncs: 0,
            incremental_syncs: 0,
        }
    }

    /// Like [`UpdateLog::new`], but seeded at step `t0`: a leader resuming
    /// from a checkpoint pushes its first increment at `t0 + 1`, so the
    /// empty log must start at the resumed step rather than 0.
    pub fn new_at(x0: Vec<f32>, increment_bytes: usize, t0: u64) -> UpdateLog {
        let mut log = UpdateLog::new(x0, increment_bytes);
        log.t = t0;
        log
    }

    pub fn c_max(&self) -> usize {
        self.c_max
    }

    pub fn t(&self) -> u64 {
        self.t
    }

    /// Record a server step's increment (instead of broadcasting it) and
    /// advance the reference hidden state.
    pub fn push(&mut self, b: Broadcast, apply: impl FnOnce(&mut Vec<f32>)) -> Result<()> {
        if b.t != self.t + 1 {
            bail!("update log: non-contiguous step {} (at {})", b.t, self.t);
        }
        apply(&mut self.x_hat);
        self.t = b.t;
        if self.log.len() == self.c_max {
            self.log.pop_front();
        }
        self.log.push_back(b);
        Ok(())
    }

    /// Like [`UpdateLog::push`] for quantized increments: decodes `b`
    /// with the server codec and advances the reference hidden state
    /// through the shard-parallel decode path on `pool` (same math as
    /// the broadcasting server's x̂ advance, bit-identical for any pool
    /// size — pass the owning server's pool to reuse its workers).
    pub fn push_quantized(
        &mut self,
        b: Broadcast,
        quant_s: &dyn Quantizer,
        pool: &ShardPool,
    ) -> Result<()> {
        if b.t != self.t + 1 {
            bail!("update log: non-contiguous step {} (at {})", b.t, self.t);
        }
        if b.absolute {
            sharded::dequantize_into(quant_s, &b.msg, &mut self.x_hat, pool)?;
        } else {
            sharded::accumulate(quant_s, &b.msg, 1.0, &mut self.x_hat, pool)?;
        }
        self.t = b.t;
        if self.log.len() == self.c_max {
            self.log.pop_front();
        }
        self.log.push_back(b);
        Ok(())
    }

    /// Build the catch-up response for a client whose replica is at
    /// `client_t` (Appendix B.1's protocol) and account its bytes.
    pub fn catch_up(&mut self, client_t: u64) -> Result<CatchUp> {
        if client_t > self.t {
            bail!("client claims t={client_t} > server t={}", self.t);
        }
        let missing = (self.t - client_t) as usize;
        let oldest_available = self.t + 1 - self.log.len().min(self.t as usize) as u64;
        let response = if missing == 0 {
            CatchUp::Increments(Vec::new())
        } else if missing <= self.log.len() && client_t + 1 >= oldest_available {
            let skip = self.log.len() - missing;
            let incs: Vec<Broadcast> = self.log.iter().skip(skip).cloned().collect();
            debug_assert_eq!(incs.first().map(|b| b.t), Some(client_t + 1));
            self.incremental_syncs += 1;
            CatchUp::Increments(incs)
        } else {
            self.full_syncs += 1;
            CatchUp::FullState {
                t: self.t,
                x_hat: self.x_hat.clone(),
                bytes: self.x_hat.len() * 4,
            }
        };
        self.bytes_sent += response.bytes() as u64;
        Ok(response)
    }

    pub fn state(&self) -> &[f32] {
        &self.x_hat
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantizedMsg;

    fn bc(t: u64, bytes: usize) -> Broadcast {
        Broadcast {
            t,
            bytes,
            msg: QuantizedMsg { payload: vec![0; bytes], d: 4 },
            absolute: false,
            codec: 0,
        }
    }

    fn log_with(n: u64, inc_bytes: usize, d: usize) -> UpdateLog {
        let mut log = UpdateLog::new(vec![0.0; d], inc_bytes);
        for t in 1..=n {
            log.push(bc(t, inc_bytes), |x| x[0] += 1.0).unwrap();
        }
        log
    }

    #[test]
    fn c_max_follows_b1_rule() {
        // model 4*100=400 bytes, increment 50 bytes -> C_max = 8
        let log = UpdateLog::new(vec![0.0; 100], 50);
        assert_eq!(log.c_max(), 8);
    }

    #[test]
    fn incremental_catch_up_in_order() {
        let mut log = log_with(5, 50, 100);
        match log.catch_up(3).unwrap() {
            CatchUp::Increments(incs) => {
                assert_eq!(incs.iter().map(|b| b.t).collect::<Vec<_>>(), vec![4, 5]);
            }
            other => panic!("expected increments, got {other:?}"),
        }
        assert_eq!(log.incremental_syncs, 1);
        assert_eq!(log.bytes_sent, 100);
    }

    #[test]
    fn up_to_date_client_costs_nothing() {
        let mut log = log_with(5, 50, 100);
        let r = log.catch_up(5).unwrap();
        assert_eq!(r.bytes(), 0);
        assert_eq!(log.bytes_sent, 0);
    }

    #[test]
    fn too_stale_gets_full_state_bounded_by_model_size() {
        // C_max = 8; after 20 steps a client at t=2 is 18 behind
        let mut log = log_with(20, 50, 100);
        match log.catch_up(2).unwrap() {
            CatchUp::FullState { t, x_hat, bytes } => {
                assert_eq!(t, 20);
                assert_eq!(x_hat[0], 20.0);
                assert_eq!(bytes, 400); // == FedBuff's full download
            }
            other => panic!("expected full state, got {other:?}"),
        }
        assert_eq!(log.full_syncs, 1);
        // B.1's claim: cost <= FedBuff's per-download cost
        assert!(log.bytes_sent <= 400);
    }

    #[test]
    fn log_evicts_beyond_c_max() {
        let log = log_with(30, 50, 100);
        assert_eq!(log.log.len(), 8);
        assert_eq!(log.log.front().unwrap().t, 23);
    }

    #[test]
    fn push_quantized_tracks_broadcasting_server() {
        use crate::quant::parse_spec;
        use crate::util::prng::Prng;
        let qs = parse_spec("qsgd:4").unwrap();
        let pool = ShardPool::new(2);
        let d = 300;
        let mut rng = Prng::new(3);
        let mut x_hat = vec![0.0f32; d];
        let mut log = UpdateLog::new(vec![0.0f32; d], qs.expected_bytes(d));
        for t in 1..=5u64 {
            let diff: Vec<f32> = (0..d).map(|i| (i as f32 * 0.1 + t as f32).sin()).collect();
            let msg = qs.quantize(&diff, &mut rng);
            qs.accumulate(&msg, 1.0, &mut x_hat).unwrap();
            let b = Broadcast { t, bytes: msg.wire_bytes(), msg, absolute: false, codec: 0 };
            log.push_quantized(b, qs.as_ref(), &pool).unwrap();
            assert_eq!(log.state(), &x_hat[..], "t={t}");
            assert_eq!(log.t(), t);
        }
        // gaps still rejected
        let msg = qs.quantize(&vec![0.0f32; d], &mut rng);
        let bad = Broadcast { t: 99, bytes: msg.wire_bytes(), msg, absolute: false, codec: 0 };
        assert!(log.push_quantized(bad, qs.as_ref(), &pool).is_err());
    }

    #[test]
    fn new_at_accepts_resumed_contiguity() {
        let mut log = UpdateLog::new_at(vec![0.0; 100], 50, 7);
        assert_eq!(log.t(), 7);
        assert!(log.push(bc(7, 50), |_| {}).is_err(), "t0 itself is already logged history");
        log.push(bc(8, 50), |x| x[0] += 1.0).unwrap();
        assert_eq!(log.t(), 8);
    }

    #[test]
    fn rejects_gaps_and_future_clients() {
        let mut log = log_with(3, 50, 100);
        assert!(log.push(bc(7, 50), |_| {}).is_err());
        assert!(log.catch_up(9).is_err());
    }

    #[test]
    fn boundary_exactly_c_max_behind_is_incremental() {
        let mut log = log_with(10, 50, 100); // C_max = 8, log holds t=3..10
        match log.catch_up(2).unwrap() {
            CatchUp::Increments(incs) => {
                assert_eq!(incs.len(), 8);
                assert_eq!(incs[0].t, 3);
            }
            other => panic!("expected increments, got {other:?}"),
        }
        // one more step behind -> full state
        match log.catch_up(1).unwrap() {
            CatchUp::FullState { .. } => {}
            other => panic!("expected full state, got {other:?}"),
        }
    }
}
