//! Quickstart: run QAFeL vs FedBuff on the built-in analytic backend and
//! print the communication savings — no artifacts or Python needed.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use qafel::config::{Algorithm, Config};
use qafel::runtime::QuadraticBackend;
use qafel::sim::SimEngine;

fn main() -> anyhow::Result<()> {
    // A small heterogeneous least-squares problem standing in for the
    // model: 128 parameters, 64 non-iid clients, gradient noise.
    let make_backend = |seed: u64| QuadraticBackend::new(128, 64, 1.0, 0.3, 0.2, 0.02, 1, seed);

    // Paper-shaped configuration: buffer K=10, bidirectional 4-bit qsgd.
    let mut cfg = Config::default();
    cfg.fl.buffer_size = 10;
    cfg.fl.client_lr = 0.15;
    cfg.fl.server_lr = 1.0;
    cfg.fl.server_momentum = 0.0;
    cfg.fl.clip_norm = 0.0; // analytic backend
    cfg.sim.concurrency = 50;
    cfg.sim.eval_every = 5;
    cfg.stop.target_accuracy = 0.95; // proxy: 1/(1 + |grad f|^2)
    cfg.stop.max_uploads = 100_000;
    cfg.stop.max_server_steps = 20_000;

    println!("algorithm        uploads   kB/up    kB/down  MB up   MB down  reached");
    for (algo, qc, qs) in [
        (Algorithm::Qafel, "qsgd:4", "qsgd:4"),
        (Algorithm::FedBuff, "none", "none"),
    ] {
        cfg.fl.algorithm = algo;
        cfg.quant.client = qc.into();
        cfg.quant.server = qs.into();
        let backend = make_backend(1);
        let r = SimEngine::new(&cfg, &backend, 1).run()?;
        let p = r.at_target();
        println!(
            "{:<16} {:>7}   {:>6.3}   {:>6.3}  {:>6.3}  {:>6.3}   {}",
            algo.name(),
            p.uploads,
            r.comm.kb_per_upload(),
            r.comm.kb_per_download(),
            p.upload_mb,
            p.broadcast_mb,
            if r.reached.is_some() { "yes" } else { "no" },
        );
    }
    println!("\nQAFeL reaches the same target with ~8x fewer uploaded bytes");
    println!("(4-bit qsgd both ways; broadcast bytes divided by a further K).");
    Ok(())
}
