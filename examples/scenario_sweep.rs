//! Scenario sweep: one QAFeL configuration under five client
//! populations — uniform (the paper's model), slow-dominated, diurnal,
//! bursty, and tiered-codec (scenario engine v2: the slow tier uploads
//! on its own `top:0.05` codec and salvages half its dropouts as
//! partial-work submissions) — showing how staleness, dropped work, and
//! achieved concurrency move with the population while memory stays
//! bounded by the number of live model versions (scenario engine,
//! DESIGN_SCENARIOS.md).
//!
//! ```sh
//! cargo run --release --example scenario_sweep
//! ```
//!
//! Output columns, one row per population:
//!
//! | column | meaning |
//! |---|---|
//! | `uploads` | client updates the server ingested (full + partial) |
//! | `steps` | server steps taken (uploads / K, minus the last partial buffer) |
//! | `tiers` | device tiers in the population |
//! | `stale-mean` / `stale-max` | staleness `tau` of ingested updates, mean and max |
//! | `dropped` | clients that trained but contributed nothing (full dropouts) |
//! | `partial` | dropped clients that still submitted their completed `m/P` prefix |
//! | `kB/up` | mean wire bytes per upload — mixes codecs under per-tier presets |
//! | `conc(avg)` | time-averaged in-flight clients (tracks `sim.concurrency`) |
//! | `snapshots` | peak live model versions in the snapshot store |
//! | `reached` | whether the run hit `stop.target_accuracy` |

use qafel::config::{Config, TierConfig};
use qafel::experiments::heterogeneity::{slow_dominated, slow_dominated_presets};
use qafel::runtime::QuadraticBackend;
use qafel::sim::SimEngine;

fn base() -> Config {
    let mut cfg = Config::default();
    cfg.fl.buffer_size = 8;
    // P >= 2 so the tiered-codec scenario's partial-work dropouts have
    // a mid-round prefix to submit; the backend below runs the same P
    cfg.fl.local_steps = 2;
    cfg.fl.client_lr = 0.12;
    cfg.fl.server_lr = 1.0;
    cfg.fl.server_momentum = 0.0;
    cfg.fl.clip_norm = 0.0;
    cfg.quant.client = "qsgd:4".into();
    cfg.quant.server = "qsgd:4".into();
    cfg.sim.concurrency = 40;
    cfg.sim.eval_every = 5;
    cfg.stop.target_accuracy = 0.95; // proxy: 1/(1 + |grad f|^2)
    cfg.stop.max_uploads = 60_000;
    cfg.stop.max_server_steps = 10_000;
    cfg
}

/// Two half-populations that sleep in counter-phase: between them the
/// system never fully stops, but each tier contributes diurnal waves.
fn diurnal(base: &Config) -> Config {
    let mut cfg = base.clone();
    let mut day = TierConfig::named("day");
    day.weight = 0.5;
    day.day_period = 20.0;
    day.on_fraction = 0.5;
    let mut night = TierConfig::named("night");
    night.weight = 0.5;
    night.day_period = 20.0;
    night.on_fraction = 0.5;
    night.phase = 10.0;
    cfg.scenario.tiers = vec![day, night];
    cfg
}

/// Flash-crowd arrivals: 6x rate bursts, ~20% of the time.
fn bursty(base: &Config) -> Config {
    let mut cfg = base.clone();
    cfg.scenario.arrival = Some("bursty".into());
    cfg.scenario.burst_factor = 6.0;
    cfg.scenario.burst_on = 2.0;
    cfg.scenario.burst_off = 8.0;
    cfg
}

fn main() -> anyhow::Result<()> {
    let base = base();
    println!(
        "{:<16} {:>8} {:>6} {:>7} {:>11} {:>10} {:>8} {:>8} {:>8} {:>10} {:>10} {:>8}",
        "scenario",
        "uploads",
        "steps",
        "tiers",
        "stale-mean",
        "stale-max",
        "dropped",
        "partial",
        "kB/up",
        "conc(avg)",
        "snapshots",
        "reached"
    );
    for (name, cfg) in [
        ("uniform", base.clone()),
        ("slow-dominated", slow_dominated(&base)),
        ("diurnal", diurnal(&base)),
        ("bursty", bursty(&base)),
        ("tiered-codec", slow_dominated_presets(&base)),
    ] {
        cfg.validate()?;
        let backend =
            QuadraticBackend::new(128, 64, 1.0, 0.3, 0.2, 0.02, cfg.fl.local_steps, 1);
        let r = SimEngine::new(&cfg, &backend, 1).run()?;
        let sc = &r.scenario;
        let dropped: u64 = sc.tiers.iter().map(|t| t.dropouts).sum();
        let partial: u64 = sc.tiers.iter().map(|t| t.partial_uploads).sum();
        println!(
            "{name:<16} {:>8} {:>6} {:>7} {:>11.2} {:>10} {:>8} {:>8} {:>8.3} {:>10.1} {:>10} {:>8}",
            r.comm.uploads,
            r.server_steps,
            sc.tiers.len(),
            sc.staleness.mean(),
            sc.staleness.max,
            dropped,
            partial,
            r.comm.kb_per_upload(),
            sc.mean_concurrency,
            sc.max_live_snapshots,
            if r.reached.is_some() { "yes" } else { "no" },
        );
    }
    println!(
        "\nsnapshots = peak live model versions in the snapshot store: memory is\n\
         O(model versions), not O(in-flight clients), at any concurrency."
    );
    Ok(())
}
