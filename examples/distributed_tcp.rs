//! Real distributed deployment on localhost TCP: one leader + N workers,
//! each worker running Algorithm 2 with its own hidden-state replica
//! (Algorithm 3) as a background thread. Every byte on the wire is the
//! same packed payload the quantizer codecs produce.
//!
//! ```sh
//! cargo run --release --example distributed_tcp -- [n_workers]
//! ```
//!
//! (The `qafel leader` / `qafel worker` subcommands run the same stack as
//! separate OS processes across machines.)

use qafel::config::{Algorithm, Config};
use qafel::net::{Leader, Worker};
use qafel::runtime::{Backend as _, QuadraticBackend};

fn main() -> anyhow::Result<()> {
    let n_workers: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    let mut cfg = Config::default();
    cfg.fl.algorithm = Algorithm::Qafel;
    cfg.quant.client = "qsgd:4".into();
    cfg.quant.server = "qsgd:4".into();
    cfg.fl.buffer_size = 4;
    cfg.fl.client_lr = 0.05;
    cfg.fl.server_lr = 1.0;
    cfg.fl.server_momentum = 0.0;
    cfg.fl.staleness_scaling = true;
    cfg.fl.clip_norm = 0.0;
    cfg.stop.max_server_steps = 100;
    cfg.stop.max_uploads = 1_000_000;

    let d = 128;
    let mk = |seed| QuadraticBackend::new(d, 64, 1.0, 0.3, 0.2, 0.02, 1, seed);
    let x0 = mk(7).init_params(0)?;
    let g0 = mk(7).grad_norm_sq(&x0);

    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    println!("[leader] 127.0.0.1 (ephemeral port), {n_workers} workers, K={}", cfg.fl.buffer_size);

    let leader_cfg = cfg.clone();
    let leader_x0 = x0.clone();
    let leader = std::thread::spawn(move || Leader::new(leader_cfg, leader_x0, 1).run_on(listener, n_workers));

    let worker_shards = cfg.fl.shards;
    let mut handles = Vec::new();
    for i in 0..n_workers {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut w = Worker::new(QuadraticBackend::new(128, 64, 1.0, 0.3, 0.2, 0.02, 1, 7));
            w.round_delay = std::time::Duration::from_millis(2);
            w.shards = worker_shards;
            let r = w.run(&addr).expect("worker failed");
            println!("[worker {i}] {} uploads, replica caught up to t={}", r.uploads, r.replica_t);
        }));
    }

    let report = leader.join().unwrap()?;
    for h in handles {
        h.join().unwrap();
    }

    let g1 = mk(7).grad_norm_sq(&report.model);
    println!("\n[leader] {} server steps, {} uploads", report.server_steps, report.comm.uploads);
    println!("[leader] kB/upload = {:.3}, kB/broadcast = {:.3}",
             report.comm.kb_per_upload(), report.comm.kb_per_download());
    println!("[leader] staleness: mean {:.2}, max {}", report.staleness_mean, report.staleness_max);
    // wire-protocol v2 per-worker accounting (negotiated codec, exact bytes)
    for ws in &report.worker_stats {
        println!(
            "[leader] worker {}: v{} codec {} — {} uploads / {:.1} kB up, \
             {} broadcast frames / {:.1} kB down, staleness mean {:.2}",
            ws.worker_id, ws.protocol, ws.codec, ws.uploads,
            ws.upload_bytes as f64 / 1000.0, ws.broadcast_frames,
            ws.broadcast_bytes as f64 / 1000.0, ws.staleness.mean(),
        );
    }
    println!("[leader] |grad f|^2: {g0:.3} -> {g1:.3}");
    Ok(())
}
