//! End-to-end driver (DESIGN.md E8): the full three-layer stack on the
//! synthetic CelebA-LEAF task.
//!
//! Loads the AOT artifacts (L2 JAX CNN + L1 Pallas kernels) into the PJRT
//! runtime, then trains the paper's model with the QAFeL coordinator in
//! the asynchronous virtual-time simulator: K = 10, bidirectional 4-bit
//! qsgd, concurrency 100, Meta-style half-normal client durations — and
//! logs the loss/accuracy curve. The run is recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_celeba
//! # optional: E2E_UPLOADS=8000 E2E_TARGET=0.9 cargo run ...
//! ```

use qafel::config::{Algorithm, Config};
use qafel::metrics::csv::CsvWriter;
use qafel::runtime::{artifacts_available, Backend as _, Engine, PjrtBackend};
use qafel::sim::{SimEngine, SimOptions};
use std::rc::Rc;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let adir = std::env::var("QAFEL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !artifacts_available(&adir) {
        anyhow::bail!("artifacts missing in '{adir}' — run `make artifacts` first");
    }

    let mut cfg = Config::default(); // paper Appendix D hyperparameters
    cfg.stop.max_uploads = env_or("E2E_UPLOADS", 6000);
    cfg.stop.target_accuracy = env_or("E2E_TARGET", 0.90);
    cfg.sim.eval_every = env_or("E2E_EVAL_EVERY", 5);
    cfg.data.eval_samples = env_or("E2E_EVAL_SAMPLES", 1024);

    eprintln!("[e2e] compiling artifacts from {adir} ...");
    let engine = Rc::new(Engine::load_subset(
        &adir,
        &["init_params", "client_update", "eval_step"],
    )?);
    eprintln!(
        "[e2e] model d = {} ({:.1} kB full-precision update; paper: 117.1 kB)",
        engine.d(),
        engine.d() as f64 * 4.0 / 1000.0
    );
    let backend = PjrtBackend::new(engine, &cfg.data, cfg.seeds[0])?;

    eprintln!(
        "[e2e] QAFeL: K={}, Qc={}, Qs={}, eta_l={:.2e}, eta_g={}, beta={}, concurrency={}",
        cfg.fl.buffer_size,
        cfg.quant.client,
        cfg.quant.server,
        cfg.fl.client_lr,
        cfg.fl.server_lr,
        cfg.fl.server_momentum,
        cfg.sim.concurrency
    );
    let opts = SimOptions { verbose: true, ..Default::default() };
    let result = SimEngine::new(&cfg, &backend, cfg.seeds[0]).run_with(&opts)?;

    // loss curve -> csv + stdout
    let mut csv = CsvWriter::new(&[
        "virtual_time", "server_steps", "uploads", "upload_mb", "broadcast_mb",
        "val_loss", "val_accuracy",
    ]);
    println!("\n  time    steps  uploads   MB-up  MB-down  val-loss  val-acc");
    for p in &result.curve {
        println!(
            "{:>7.2} {:>7} {:>8} {:>7.2} {:>8.3} {:>9.4} {:>8.4}",
            p.time, p.server_steps, p.uploads, p.upload_mb, p.broadcast_mb,
            p.val_loss, p.val_accuracy
        );
        csv.row(&[
            format!("{:.3}", p.time),
            p.server_steps.to_string(),
            p.uploads.to_string(),
            format!("{:.4}", p.upload_mb),
            format!("{:.4}", p.broadcast_mb),
            format!("{:.5}", p.val_loss),
            format!("{:.5}", p.val_accuracy),
        ]);
    }
    std::fs::create_dir_all("reports")?;
    csv.save("reports/e2e_celeba_curve.csv")?;
    eprintln!("[e2e] curve written to reports/e2e_celeba_curve.csv");

    println!("\nsummary:");
    println!("  wall time      : {:.1}s", result.wall_seconds);
    println!("  server steps   : {}", result.server_steps);
    println!("  uploads        : {}", result.comm.uploads);
    println!("  kB/upload      : {:.3} (fedbuff would be {:.3})",
             result.comm.kb_per_upload(), backend.d() as f64 * 4.0 / 1000.0);
    println!("  MB uploaded    : {:.2}", result.comm.upload_mb());
    println!("  MB broadcast   : {:.2}", result.comm.broadcast_mb());
    println!("  final val acc  : {:.4}", result.final_accuracy);
    match result.reached {
        Some(p) => println!(
            "  reached {:.0}% at {} uploads / {:.2} MB uploaded",
            cfg.stop.target_accuracy * 100.0, p.uploads, p.upload_mb
        ),
        None => println!("  target {:.0}% not reached within the upload cap",
                         cfg.stop.target_accuracy * 100.0),
    }
    Ok(())
}

// silence unused-import warning for Algorithm in docs
#[allow(unused)]
fn _algo_doc(a: Algorithm) -> &'static str {
    a.name()
}
