//! Bandwidth-constrained design (the paper's conclusion: "This approach
//! can be used to design FL systems with bandwidth constraints").
//!
//! Given a per-upload byte budget, enumerate the quantizer configurations
//! that fit, simulate each, and report which reaches the target accuracy
//! with the least *total* traffic — exposing the paper's trade-off that
//! compressing harder sends fewer bytes per message but more messages.
//!
//! ```sh
//! cargo run --release --example bandwidth_budget -- [budget_bytes]
//! ```

use qafel::config::{Algorithm, Config};
use qafel::quant::parse_spec;
use qafel::runtime::{Backend as _, QuadraticBackend};
use qafel::sim::SimEngine;

const D: usize = 256;

fn main() -> anyhow::Result<()> {
    let budget: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200); // bytes per upload

    let mut cfg = Config::default();
    cfg.fl.buffer_size = 8;
    cfg.fl.client_lr = 0.12;
    cfg.fl.server_lr = 1.0;
    cfg.fl.server_momentum = 0.0;
    cfg.fl.clip_norm = 0.0;
    cfg.sim.concurrency = 40;
    cfg.sim.eval_every = 5;
    cfg.stop.target_accuracy = 0.95;
    cfg.stop.max_uploads = 150_000;
    cfg.stop.max_server_steps = 40_000;

    let candidates = [
        "qsgd:8", "qsgd:6", "qsgd:4", "qsgd:3", "qsgd:2",
        "top:0.25", "top:0.1", "rand:0.25", "none",
    ];

    println!("per-upload budget: {budget} bytes (model d = {D}, full precision = {} bytes)\n", 4 * D);
    println!("quantizer     bytes/up  fits  uploads  total-MB-up  reached");
    let mut best: Option<(String, f64)> = None;
    for spec in candidates {
        let q = parse_spec(spec)?;
        let bytes = q.expected_bytes(D);
        let fits = bytes <= budget;
        if !fits {
            println!("{spec:<12} {bytes:>9}   no        -            -        -");
            continue;
        }
        cfg.fl.algorithm = Algorithm::Qafel;
        cfg.quant.client = spec.to_string();
        cfg.quant.server = "qsgd:4".to_string();
        let backend = QuadraticBackend::new(D, 32, 1.0, 0.3, 0.2, 0.02, 1, 1);
        let r = SimEngine::new(&cfg, &backend, 1).run()?;
        let p = r.at_target();
        let reached = r.reached.is_some();
        println!(
            "{spec:<12} {bytes:>9}   yes {:>9} {:>12.3}     {}",
            p.uploads,
            p.upload_mb,
            if reached { "yes" } else { "no " }
        );
        if reached {
            let better = best.as_ref().map(|(_, mb)| p.upload_mb < *mb).unwrap_or(true);
            if better {
                best = Some((spec.to_string(), p.upload_mb));
            }
        }
        let _ = backend.d();
    }
    match best {
        Some((spec, mb)) => println!(
            "\nbest within budget: {spec} ({mb:.3} MB total upload to target)"
        ),
        None => println!("\nno in-budget quantizer reached the target — raise the budget"),
    }
    Ok(())
}
