#!/usr/bin/env python3
"""Validate `qafel leader --report-json` from the adversarial net-e2e leg.

The robustness CI job runs a real leader with `[fl.robust]` enabled plus
N worker processes on loopback, one of them launched with
`--adversary sign_flip` (or `scale:<c>`). This check asserts, from the
leader's JSON report:

* the run completed the configured number of server steps and every
  worker joined on protocol v2 and uploaded at least once;
* byte accounting is **exact** per worker (`upload_bytes == uploads *
  expected_bytes_per_upload`) and per-worker totals sum to the server's
  totals — corrupting payload *values* must not change payload *sizes*;
* the report carries the `robust` config block and per-worker
  `clipped_updates` / `trimmed_updates` counters, all consistent with
  the aggregation rule the run used;
* the rule-specific exclusion invariant:
  - ``--rule trim``: the trimmed mean **excluded the adversary** — the
    adversarial worker has `trimmed_updates > 0` and its exclusion rate
    (trimmed/uploads) strictly exceeds the honest workers' mean rate
    (sign flips are per-coordinate extremes, honest updates agree);
  - ``--rule clip``: the adversarial worker has `clipped_updates > 0`
    and a higher clip rate than the honest mean (for large-norm
    attacks such as `scale:50`);
  - ``--rule mean``: every robust counter is zero and the `robust`
    block reports disabled — the undefended baseline.

Usage:
  check_robustness.py report.json --steps N --workers N
                      --adversary-worker ID --rule trim|clip|mean
                      [--max-grad-ratio X]

Exit code 0 when the report validates, 1 otherwise.
"""

import argparse
import math
import sys

from checklib import Checker, load_json


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("report")
    ap.add_argument("--steps", type=int, required=True)
    ap.add_argument("--workers", type=int, required=True)
    ap.add_argument("--adversary-worker", type=int, required=True,
                    help="worker_id launched with --adversary")
    ap.add_argument("--rule", choices=["trim", "clip", "mean"], required=True,
                    help="the [fl.robust] aggregation rule the leader ran")
    ap.add_argument("--max-grad-ratio", type=float, default=None,
                    help="require grad_ratio < X (the defended run still descends)")
    args = ap.parse_args()

    checker = Checker(args.report)
    check = checker.check
    doc, problem = load_json(args.report)
    if problem:
        checker.fail(problem)
        return checker.finish()

    check(doc.get("server_steps") == args.steps,
          f"server_steps {doc.get('server_steps')} != {args.steps}")
    check(doc.get("broadcasts") == args.steps,
          f"broadcasts {doc.get('broadcasts')} != {args.steps}")
    ratio = doc.get("grad_ratio")
    check(isinstance(ratio, (int, float)) and math.isfinite(ratio),
          f"grad_ratio missing or non-finite: {ratio!r}")
    if args.max_grad_ratio is not None and isinstance(ratio, (int, float)):
        check(ratio < args.max_grad_ratio,
              f"defended run did not descend: grad_ratio {ratio} >= {args.max_grad_ratio}")

    # the robust config block must match the rule under test
    robust = doc.get("robust")
    check(isinstance(robust, dict), f"missing 'robust' config block: {robust!r}")
    robust = robust if isinstance(robust, dict) else {}
    if args.rule == "mean":
        check(robust.get("enabled") is False,
              f"rule mean but robust.enabled = {robust.get('enabled')!r}")
    else:
        check(robust.get("enabled") is True,
              f"rule {args.rule} but robust.enabled = {robust.get('enabled')!r}")
    if args.rule == "trim":
        check(isinstance(robust.get("trim_frac"), (int, float)) and robust["trim_frac"] > 0,
              f"rule trim but trim_frac = {robust.get('trim_frac')!r}")
    if args.rule == "clip":
        check(isinstance(robust.get("clip_norm"), (int, float)) and robust["clip_norm"] > 0,
              f"rule clip but clip_norm = {robust.get('clip_norm')!r}")

    workers = doc.get("workers")
    check(isinstance(workers, list) and len(workers) == args.workers,
          f"expected {args.workers} worker entries, got "
          f"{len(workers) if isinstance(workers, list) else workers!r}")
    workers = workers if isinstance(workers, list) else []

    total_uploads = 0
    total_bytes = 0
    adversary = None
    honest = []
    for w in workers:
        wid = w.get("worker_id")
        check(w.get("protocol") == 2, f"worker {wid}: protocol {w.get('protocol')} != 2")
        uploads = w.get("uploads", 0)
        check(uploads > 0, f"worker {wid}: never uploaded")
        # exact byte accounting: the adversary corrupts values, never sizes
        expected = w.get("expected_bytes_per_upload", 0)
        check(expected > 0, f"worker {wid}: bad expected_bytes_per_upload {expected!r}")
        check(w.get("upload_bytes") == uploads * expected,
              f"worker {wid} ({w.get('codec')}): upload_bytes {w.get('upload_bytes')} != "
              f"{uploads} uploads x {expected} B")
        for key in ("clipped_updates", "trimmed_updates"):
            v = w.get(key)
            check(isinstance(v, (int, float)) and not isinstance(v, bool) and v >= 0,
                  f"worker {wid}: bad {key} {v!r}")
            check(not isinstance(v, (int, float)) or v <= uploads,
                  f"worker {wid}: {key} {v} exceeds {uploads} uploads")
        total_uploads += uploads
        total_bytes += w.get("upload_bytes", 0)
        if wid == args.adversary_worker:
            adversary = w
        else:
            honest.append(w)
    check(total_uploads == doc.get("uploads"),
          f"per-worker uploads {total_uploads} != server total {doc.get('uploads')}")
    check(total_bytes == doc.get("upload_bytes"),
          f"per-worker bytes {total_bytes} != server total {doc.get('upload_bytes')}")
    check(adversary is not None,
          f"no worker row with the adversarial id {args.adversary_worker}")
    check(bool(honest), "no honest workers to compare against")

    def rate(w, key):
        return w.get(key, 0) / max(w.get("uploads", 0), 1)

    if adversary is not None and honest:
        if args.rule == "trim":
            # the headline invariant: the trimmed mean excludes the
            # adversary. Sign-flipped updates are per-coordinate extremes
            # against an honest majority, so the adversary's rows are
            # trimmed at a strictly higher rate than the honest mean.
            check(adversary.get("trimmed_updates", 0) > 0,
                  "trimmed mean never excluded the adversary")
            adv_rate = rate(adversary, "trimmed_updates")
            honest_mean = sum(rate(w, "trimmed_updates") for w in honest) / len(honest)
            check(adv_rate > honest_mean,
                  f"adversary trim rate {adv_rate:.3f} not above honest mean "
                  f"{honest_mean:.3f}")
        elif args.rule == "clip":
            check(adversary.get("clipped_updates", 0) > 0,
                  "clipping never bounded the adversary")
            adv_rate = rate(adversary, "clipped_updates")
            honest_mean = sum(rate(w, "clipped_updates") for w in honest) / len(honest)
            check(adv_rate > honest_mean,
                  f"adversary clip rate {adv_rate:.3f} not above honest mean "
                  f"{honest_mean:.3f}")
        else:  # mean: the undefended baseline records nothing
            for w in workers:
                wid = w.get("worker_id")
                check(w.get("clipped_updates", 0) == 0,
                      f"worker {wid}: clipped_updates {w.get('clipped_updates')} "
                      f"with robust aggregation off")
                check(w.get("trimmed_updates", 0) == 0,
                      f"worker {wid}: trimmed_updates {w.get('trimmed_updates')} "
                      f"with robust aggregation off")

    detail = f"rule {args.rule}, {args.workers} workers, {args.steps} steps"
    if adversary is not None:
        detail += (f", adversary {args.adversary_worker}: "
                   f"{adversary.get('clipped_updates', 0)} clipped / "
                   f"{adversary.get('trimmed_updates', 0)} trimmed "
                   f"of {adversary.get('uploads', 0)} uploads")
    return checker.finish(detail)


if __name__ == "__main__":
    sys.exit(main())
