#!/usr/bin/env python3
"""Offline markdown link checker for the repo's doc surface (CI).

Checks every inline markdown link `[text](target)` in the given files:

* relative file targets must exist (checked relative to the linking
  file's directory; a `#fragment` suffix is stripped first);
* `#fragment`-only targets must match a heading in the same file
  (GitHub anchor slugging: lowercase, punctuation stripped, spaces to
  dashes);
* absolute `http(s)://` / `mailto:` targets are skipped — CI runs
  offline, and external rot is not this check's job.

Exit code 0 when every link resolves, 1 otherwise (each failure is
printed as `file: broken link 'target'`).
"""

import re
import sys
from pathlib import Path

# inline links only; reference-style links are not used in this repo.
# [text](target) with no nesting; ignore images' leading '!' (same rule).
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    heading = heading.strip().lower()
    heading = re.sub(r"[^\w\- ]", "", heading)
    return heading.replace(" ", "-")


def check_file(path: Path) -> list[str]:
    # drop fenced code blocks first: link-looking text inside them is
    # code, and '#'-prefixed shell/TOML comments are not headings
    text = CODE_FENCE_RE.sub("", path.read_text(encoding="utf-8"))
    anchors = {github_slug(h) for h in HEADING_RE.findall(text)}
    failures = []
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):
            if github_slug(target[1:]) not in anchors:
                failures.append(f"{path}: broken anchor '{target}'")
            continue
        rel = target.split("#", 1)[0]
        if not (path.parent / rel).exists():
            failures.append(f"{path}: broken link '{target}'")
    return failures


def main(argv: list[str]) -> int:
    if len(argv) < 2:
        print("usage: check_links.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    failures = []
    for name in argv[1:]:
        path = Path(name)
        if not path.exists():
            failures.append(f"{path}: file does not exist")
            continue
        failures.extend(check_file(path))
    for f in failures:
        print(f, file=sys.stderr)
    if not failures:
        print(f"check_links: {len(argv) - 1} files OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
