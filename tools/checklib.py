"""Shared helpers for the `tools/check_*.py` CI validators.

Every validator follows the same shape: load a JSON artifact, accumulate
human-readable problem strings, print them to stderr and exit non-zero
if any. The pieces that were copy-pasted between `check_bench.py`,
`check_journal.py` and `check_net_e2e.py` live here instead:

* ``load_json(path)`` — parse a JSON file, returning ``(doc, problem)``
  where exactly one side is ``None``;
* ``numeric(doc, field, positive)`` — require a finite number, either
  strictly positive or merely non-negative;
* ``hex_bytes(s, what, errs)`` — decode an even-length hex string,
  appending problems and returning the byte length;
* ``Checker`` — a named problem accumulator with the standard
  ``name: problem`` stderr / ``name: ok`` stdout reporting.

No third-party imports — CI runs these on the stock interpreter.
"""

import json
import math
import sys
from pathlib import Path


def load_json(path):
    """Parse a JSON file. Returns ``(doc, None)`` or ``(None, problem)``."""
    try:
        doc = json.loads(Path(path).read_text(encoding="utf-8"))
    except (OSError, json.JSONDecodeError) as e:
        return None, f"unreadable: {e}"
    return doc, None


def numeric(doc, field, positive):
    """Problems for a required finite numeric field.

    ``positive=True`` requires ``> 0`` (a zero counter means the thing
    never ran); ``positive=False`` allows zero but rejects negatives.
    Returns a list of problem strings (empty when the field is fine).
    """
    if field not in doc:
        return [f"missing key '{field}'"]
    v = doc[field]
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return [f"'{field}' must be a number, got {v!r}"]
    if not math.isfinite(v):
        return [f"'{field}' must be finite, got {v!r}"]
    if positive and v <= 0:
        return [f"'{field}' must be > 0, got {v!r}"]
    if not positive and v < 0:
        return [f"'{field}' must be >= 0, got {v!r}"]
    return []


def hex_bytes(s, what, errs):
    """Decode a lowercase-hex byte string, returning its byte length.

    Appends a problem to ``errs`` (and returns 0) when the string is not
    valid even-length hex.
    """
    if not isinstance(s, str) or len(s) % 2 != 0:
        errs.append(f"{what}: not an even-length hex string")
        return 0
    try:
        return len(bytes.fromhex(s))
    except ValueError:
        errs.append(f"{what}: invalid hex")
        return 0


class Checker:
    """Accumulate problems for one artifact and report them CI-style."""

    def __init__(self, name):
        self.name = name
        self.problems = []

    def check(self, cond, msg):
        if not cond:
            self.problems.append(msg)

    def fail(self, msg):
        self.problems.append(msg)

    def finish(self, ok_detail=""):
        """Print ``name: problem`` lines (stderr) or one ``name: ok``
        line (stdout); returns the process exit code (0 ok, 1 not)."""
        for p in self.problems:
            print(f"{self.name}: {p}", file=sys.stderr)
        if not self.problems:
            detail = f" ({ok_detail})" if ok_detail else ""
            print(f"{self.name}: ok{detail}")
        return 1 if self.problems else 0
