#!/usr/bin/env python3
"""Schema + invariant check for flight-recorder journals (CI).

A journal is the JSONL event log a `qafel run --journal` / `qafel
leader --journal` writes (ARCHITECTURE.md §Telemetry). The Rust side
already proves semantic bit-identity via `qafel journal replay`; this
validator independently pins the *format* contract an external consumer
relies on, without linking the crate:

* every line is a standalone JSON object with a known `ev` discriminant
  (a torn final line — a kill mid-write — is tolerated and reported);
* the first event is `meta` with runtime/algorithm/d/seed/fingerprint,
  and `init`/`codec` registration precedes any traffic;
* hex payload fields decode (even length, hex digits); `init.x0` and
  `final.model` are exactly `4*d` bytes of little-endian f32;
* `step` events count 1, 2, 3, ... with nondecreasing `time` and
  nondecreasing cumulative upload/broadcast byte totals;
* each `step` is followed by its `broadcast` (same step number), and
  `final`, when present, is the last event with totals matching the
  last `step`;
* `rekey` events (the adaptive controller switching a worker's upload
  codec mid-run) carry the full old->new transition and never precede
  `init`.

Usage: check_journal.py RUN.jsonl [RUN2.jsonl ...]
       [--steps N]    require exactly N server steps
       [--final]      require a final event (completed run)

Exit code 0 when every file validates, 1 otherwise (each failure is
printed as `file:line: problem`).
"""

import argparse
import json
import sys
from pathlib import Path

from checklib import hex_bytes

KNOWN_EVENTS = {
    "meta",
    "codec",
    "init",
    "arrival",
    "ingest",
    "ingest_partial",
    "step",
    "broadcast",
    "eval",
    "checkpoint",
    "final",
    "rekey",
}

REQUIRED = {
    "meta": ["runtime", "algorithm", "d", "seed", "fingerprint", "config"],
    "codec": ["reg", "id", "spec"],
    "init": ["x0", "server_seed"],
    "arrival": ["time", "tier", "user", "trip", "t_start", "dropped"],
    "ingest": ["time", "step", "worker", "codec", "staleness", "payload"],
    "ingest_partial": [
        "time",
        "step",
        "worker",
        "codec",
        "count",
        "stale_counts",
        "stale_sum",
        "stale_max",
        "stale_n",
        "payload",
    ],
    "step": [
        "time",
        "step",
        "k",
        "uploads",
        "upload_bytes",
        "broadcast_bytes",
        "stale_mean",
        "stale_max",
    ],
    "broadcast": ["time", "step", "absolute", "payload"],
    "rekey": ["time", "step", "worker", "old", "new", "spec"],
    "eval": ["time", "step", "uploads", "val_loss", "val_accuracy"],
    "checkpoint": ["time", "step", "state"],
    "final": [
        "step",
        "uploads",
        "upload_bytes",
        "broadcasts",
        "broadcast_bytes",
        "model",
    ],
}

HEX_FIELDS = {
    "init": ["x0"],
    "ingest": ["payload"],
    "ingest_partial": ["payload"],
    "broadcast": ["payload"],
    "final": ["model"],
}


def check_file(path, want_steps=None, want_final=False):
    errs = []
    lines = Path(path).read_text().split("\n")
    while lines and lines[-1] == "":
        lines.pop()
    events = []  # (lineno, dict)
    for i, line in enumerate(lines, 1):
        if not line:
            errs.append(f"{path}:{i}: empty interior line")
            continue
        try:
            ev = json.loads(line)
        except json.JSONDecodeError:
            if i == len(lines):
                print(f"{path}:{i}: note: torn tail line dropped (killed run)")
                continue
            errs.append(f"{path}:{i}: unparsable line (not the tail — corruption)")
            continue
        events.append((i, ev))

    def err(lineno, msg):
        errs.append(f"{path}:{lineno}: {msg}")

    if not events:
        errs.append(f"{path}: no events")
        return errs

    # schema: every event known, required keys present, hex fields decode
    d = None
    for lineno, ev in events:
        kind = ev.get("ev")
        if kind not in KNOWN_EVENTS:
            err(lineno, f"unknown event kind {kind!r}")
            continue
        for key in REQUIRED[kind]:
            if key not in ev:
                err(lineno, f"{kind}: missing field {key!r}")
        for key in HEX_FIELDS.get(kind, []):
            if key in ev:
                n = hex_bytes(ev[key], f"{kind}.{key}", errs)
                if kind in ("init", "final") and d is not None and n != 4 * d:
                    err(lineno, f"{kind}.{key}: {n} bytes, want 4*d = {4 * d}")
        if kind == "meta":
            d = ev.get("d")
        if kind == "rekey" and ev.get("old") == ev.get("new"):
            err(lineno, f"rekey: old == new == {ev.get('old')} (no-op switch)")

    # ordering: meta first, init/codec before traffic
    first_lineno, first = events[0]
    if first.get("ev") != "meta":
        err(first_lineno, f"first event is {first.get('ev')!r}, not meta")
    kinds = [e.get("ev") for _, e in events]
    if "init" not in kinds:
        err(first_lineno, "no init event")
    else:
        init_at = kinds.index("init")
        for lineno, ev in events[:init_at]:
            if ev.get("ev") in ("ingest", "ingest_partial", "step", "broadcast", "rekey"):
                err(lineno, f"{ev['ev']} before init")

    # step monotonicity + totals + broadcast pairing
    prev_step = 0
    prev_time = None
    prev_up = 0
    prev_down = 0
    last_step_ev = None
    pending_broadcast = None  # step number awaiting its broadcast event
    for lineno, ev in events:
        kind = ev.get("ev")
        if kind == "step":
            t = ev.get("step")
            if t != prev_step + 1:
                err(lineno, f"step {t} after step {prev_step} (want {prev_step + 1})")
            prev_step = t if isinstance(t, int) else prev_step + 1
            if ev.get("time") is not None:
                if prev_time is not None and ev["time"] < prev_time:
                    err(lineno, f"step time {ev['time']} < previous {prev_time}")
                prev_time = ev["time"]
            up, down = ev.get("upload_bytes", 0), ev.get("broadcast_bytes", 0)
            if up < prev_up or down < prev_down:
                err(lineno, "cumulative byte totals decreased")
            prev_up, prev_down = up, down
            if pending_broadcast is not None:
                err(lineno, f"step {t} before broadcast of step {pending_broadcast}")
            pending_broadcast = t
            last_step_ev = ev
        elif kind == "broadcast":
            if ev.get("step") != pending_broadcast:
                err(
                    lineno,
                    f"broadcast for step {ev.get('step')}, "
                    f"expected {pending_broadcast}",
                )
            pending_broadcast = None

    # final: last event, totals consistent with the last step
    finals = [(lineno, ev) for lineno, ev in events if ev.get("ev") == "final"]
    if want_final and not finals:
        errs.append(f"{path}: no final event (run did not complete)")
    for lineno, ev in finals:
        if (lineno, ev) != events[-1]:
            err(lineno, "final is not the last event")
        if ev.get("step") != prev_step:
            err(lineno, f"final.step {ev.get('step')} != last step {prev_step}")
        if last_step_ev is not None:
            for key in ("uploads", "upload_bytes", "broadcast_bytes"):
                if ev.get(key) != last_step_ev.get(key):
                    err(lineno, f"final.{key} != last step's {key}")

    if want_steps is not None and prev_step != want_steps:
        errs.append(f"{path}: {prev_step} steps, want {want_steps}")
    return errs


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("journals", nargs="+")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--final", action="store_true")
    args = ap.parse_args()
    failures = []
    for path in args.journals:
        errs = check_file(path, want_steps=args.steps, want_final=args.final)
        if errs:
            failures.extend(errs)
        else:
            print(f"{path}: OK")
    for f in failures:
        print(f, file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
