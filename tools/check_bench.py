#!/usr/bin/env python3
"""Schema check for the BENCH_*.json perf logs (CI).

The bench smoke writes `BENCH_sharded_step.json`, `BENCH_tree_step.json`
and `BENCH_scenario_step.json`; CI uploads them as workflow artifacts so
measured numbers can be checked in from a real machine (ROADMAP item).
This validator pins the format those check-ins must satisfy: required
keys present, numeric fields finite, counters/timings positive where
zero would mean the bench did not actually run.

Usage: check_bench.py BENCH_a.json [BENCH_b.json ...]

Exit code 0 when every file validates, 1 otherwise (each failure is
printed as `file: problem`).
"""

import sys
from pathlib import Path

from checklib import load_json, numeric

# field -> must be strictly positive (False allows zero, e.g. dropouts)
SHARDED_ROW_FIELDS = {
    "d": True,
    "shards": True,
    "k_buffer": True,
    "steps_timed": True,
    "ns_per_step": True,
    "steps_per_sec": True,
    "speedup_vs_s1": True,
}

TREE_ROW_FIELDS = {
    "edges": False,  # 0 = the flat baseline row
    "d": True,
    "k_buffer": True,
    "edge_buffer": False,  # 0 on the flat row
    "updates": True,
    "server_steps": True,
    "ns_per_update": True,
    "updates_per_sec": True,
    "speedup_vs_flat": True,
}

SCENARIO_FIELDS = {
    "tiers": True,
    "target_concurrency": True,
    "arrivals": True,
    "uploads": True,
    "dropouts": False,
    "server_steps": True,
    "wall_seconds": True,
    "events_per_sec": True,
    "uploads_per_sec": True,
    "mean_concurrency": True,
    "max_in_flight": True,
    "max_live_snapshots": True,
}


def check_sharded(doc: dict) -> list[str]:
    problems = []
    if not isinstance(doc.get("fast_mode"), bool):
        problems.append("'fast_mode' must be a bool")
    problems += numeric(doc, "threads_available", positive=True)
    rows = doc.get("results")
    if not isinstance(rows, list) or not rows:
        return problems + ["'results' must be a non-empty array"]
    codecs = set()
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            problems.append(f"results[{i}] must be an object")
            continue
        if not isinstance(row.get("codec"), str) or not row["codec"]:
            problems.append(f"results[{i}]: 'codec' must be a non-empty string")
        else:
            codecs.add(row["codec"])
        for field, positive in SHARDED_ROW_FIELDS.items():
            problems += [f"results[{i}]: {p}" for p in numeric(row, field, positive)]
    # the sweep must cover the biased codecs too (ROADMAP: tune the S>1
    # threshold incl. the top:0.1 / rand:0.1 rows)
    for want in ("qsgd:4", "top:0.1", "rand:0.1"):
        if want not in codecs:
            problems.append(f"results missing codec '{want}' rows")
    return problems


def check_tree(doc: dict) -> list[str]:
    problems = []
    fast = doc.get("fast_mode")
    if not isinstance(fast, bool):
        problems.append("'fast_mode' must be a bool")
    problems += numeric(doc, "threads_available", positive=True)
    for key in ("codec", "partial_codec"):
        if not isinstance(doc.get(key), str) or not doc[key]:
            problems.append(f"'{key}' must be a non-empty string")
    rows = doc.get("results")
    if not isinstance(rows, list) or not rows:
        return problems + ["'results' must be a non-empty array"]
    edges_seen = set()
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            problems.append(f"results[{i}] must be an object")
            continue
        for field, positive in TREE_ROW_FIELDS.items():
            problems += [f"results[{i}]: {p}" for p in numeric(row, field, positive)]
        if isinstance(row.get("edges"), (int, float)):
            edges_seen.add(int(row["edges"]))
    # flat baseline + the sweep the acceptance criterion names
    for want in (0, 1, 8, 32):
        if want not in edges_seen:
            problems.append(f"results missing edges={want} row (have {sorted(edges_seen)})")
    # acceptance: the 32-edge tree meets or beats flat-server throughput.
    # Enforced on full runs only: the fast-mode smoke runs a small d
    # where thread overhead legitimately dominates (documented proxy).
    if fast is False:
        for row in rows:
            if isinstance(row, dict) and row.get("edges") == 32:
                s = row.get("speedup_vs_flat")
                if isinstance(s, (int, float)) and s < 1.0:
                    problems.append(
                        f"32-edge tree slower than flat: speedup_vs_flat {s} < 1.0")
    return problems


def check_scenario(doc: dict) -> list[str]:
    problems = []
    if not isinstance(doc.get("fast_mode"), bool):
        problems.append("'fast_mode' must be a bool")
    for field, positive in SCENARIO_FIELDS.items():
        problems += numeric(doc, field, positive)
    return problems


def check_file(path: Path) -> list[str]:
    doc, problem = load_json(path)
    if problem:
        return [problem]
    if not isinstance(doc, dict):
        return ["top level must be an object"]
    bench = doc.get("bench")
    if bench == "sharded_step":
        return check_sharded(doc)
    if bench == "tree_step":
        return check_tree(doc)
    if bench == "scenario_step":
        return check_scenario(doc)
    return [f"unknown 'bench' kind {bench!r} (want sharded_step | tree_step | scenario_step)"]


def main(argv: list[str]) -> int:
    if not argv:
        print(__doc__, file=sys.stderr)
        return 2
    failures = 0
    for name in argv:
        problems = check_file(Path(name))
        for p in problems:
            print(f"{name}: {p}", file=sys.stderr)
        failures += len(problems)
        if not problems:
            print(f"{name}: ok")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
