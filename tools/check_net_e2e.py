#!/usr/bin/env python3
"""Validate a `qafel leader --report-json` file from the CI loopback E2E.

The net-e2e job runs a real leader process plus N worker processes on
loopback with heterogeneous per-worker codecs (wire protocol v2). This
check asserts, from the leader's JSON report:

* the run completed the configured number of server steps and the
  quadratic objective descended (`grad_ratio` < the bound);
* every worker joined on protocol v2, uploaded at least once, and its
  byte accounting is **exact**: `upload_bytes == uploads *
  expected_bytes_per_upload`, where upload_bytes is counted off the
  wire frames and expected_bytes_per_upload comes from the codec
  formula — two independent measurements;
* the set of negotiated per-worker codecs is exactly the requested one;
* per-worker totals sum to the server's totals.

Usage:
  check_net_e2e.py report.json --steps N --workers N --codecs a,b,c
                   [--max-grad-ratio 0.9]
"""

import argparse
import json
import math
import sys
from pathlib import Path


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("report")
    ap.add_argument("--steps", type=int, required=True)
    ap.add_argument("--workers", type=int, required=True)
    ap.add_argument("--codecs", required=True, help="comma-separated expected codec multiset")
    ap.add_argument("--max-grad-ratio", type=float, default=0.9)
    args = ap.parse_args()

    doc = json.loads(Path(args.report).read_text(encoding="utf-8"))
    problems: list[str] = []

    def check(cond: bool, msg: str) -> None:
        if not cond:
            problems.append(msg)

    check(doc.get("server_steps") == args.steps,
          f"server_steps {doc.get('server_steps')} != {args.steps}")
    check(doc.get("broadcasts") == args.steps,
          f"broadcasts {doc.get('broadcasts')} != {args.steps}")
    ratio = doc.get("grad_ratio")
    check(isinstance(ratio, (int, float)) and math.isfinite(ratio),
          f"grad_ratio missing or non-finite: {ratio!r}")
    if isinstance(ratio, (int, float)) and math.isfinite(ratio):
        check(ratio < args.max_grad_ratio,
              f"run did not converge: grad_ratio {ratio} >= {args.max_grad_ratio}")

    workers = doc.get("workers")
    check(isinstance(workers, list) and len(workers) == args.workers,
          f"expected {args.workers} worker entries, got "
          f"{len(workers) if isinstance(workers, list) else workers!r}")
    workers = workers if isinstance(workers, list) else []

    got_codecs = sorted(w.get("codec", "?") for w in workers)
    want_codecs = sorted(args.codecs.split(","))
    check(got_codecs == want_codecs,
          f"negotiated codecs {got_codecs} != requested {want_codecs}")

    total_uploads = 0
    total_bytes = 0
    for w in workers:
        wid = w.get("worker_id")
        check(w.get("protocol") == 2, f"worker {wid}: protocol {w.get('protocol')} != 2")
        uploads = w.get("uploads", 0)
        check(uploads > 0, f"worker {wid}: never uploaded")
        expected = w.get("expected_bytes_per_upload", 0)
        check(expected > 0, f"worker {wid}: bad expected_bytes_per_upload {expected!r}")
        check(w.get("upload_bytes") == uploads * expected,
              f"worker {wid} ({w.get('codec')}): upload_bytes {w.get('upload_bytes')} != "
              f"{uploads} uploads x {expected} B")
        # every live worker's writer delivered all broadcasts + Shutdown
        check(w.get("broadcast_frames") == args.steps + 1,
              f"worker {wid}: broadcast_frames {w.get('broadcast_frames')} != {args.steps + 1}")
        total_uploads += uploads
        total_bytes += w.get("upload_bytes", 0)
    check(total_uploads == doc.get("uploads"),
          f"per-worker uploads {total_uploads} != server total {doc.get('uploads')}")
    check(total_bytes == doc.get("upload_bytes"),
          f"per-worker bytes {total_bytes} != server total {doc.get('upload_bytes')}")

    for p in problems:
        print(f"{args.report}: {p}", file=sys.stderr)
    if not problems:
        print(f"{args.report}: ok ({args.workers} workers, {args.steps} steps, "
              f"codecs {', '.join(want_codecs)}, grad_ratio {ratio:.4f})")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
