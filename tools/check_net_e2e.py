#!/usr/bin/env python3
"""Validate `qafel leader --report-json` files from the CI loopback E2E.

The net-e2e job runs a real leader process plus N worker processes on
loopback with heterogeneous per-worker codecs (wire protocol v2). This
check asserts, from the leader's JSON report:

* the run completed the configured number of server steps and the
  quadratic objective descended (`grad_ratio` < the bound);
* every worker joined on protocol v2, uploaded at least once, and its
  byte accounting is **exact**: `upload_bytes == uploads *
  expected_bytes_per_upload`, where upload_bytes is counted off the
  wire frames and expected_bytes_per_upload comes from the codec
  formula — two independent measurements;
* the set of negotiated per-worker codecs is exactly the requested one;
* per-worker totals sum to the server's totals;
* downlink byte accounting is exact per worker: a Broadcast frame is
  `expected_bytes_per_download + 18` on the wire (4 B length prefix +
  14 B header) and the Shutdown frame is 5 B, so a worker whose folds
  never shipped a full-state `Sync` must show `broadcast_bytes ==
  steps * (expected_bytes_per_download + 18) + 5` — catch-up
  *increments* replay the exact evicted payloads, so the formula
  survives budget evictions; only a `Sync` (17 B + 4 B/coordinate)
  changes it;
* skip/fold consistency under `net.broadcast_budget_bytes`: skipped
  broadcasts imply catch-up frames (the throttled worker's gap was
  folded, not dropped), and catch-up frames imply skips;
* with `--downlinks`, the negotiated per-tier downlink codec multiset
  (`server_codec`) is exactly the requested one.

Adaptive mode (`--adaptive`): the run had `net.adaptive` enabled, so
workers may have been rekeyed to cheaper codecs mid-run. The checks
change shape:

* `--codecs` compares the *join-time* codecs (epoch 0), since final
  codecs are whatever the controller negotiated;
* upload accounting is exact **per codec epoch**: each epoch's
  `upload_bytes == uploads * expected_bytes_per_upload`, and the epochs
  sum to the worker's totals — in-flight old-codec uploads accepted
  during a transition window attribute to their own epoch, so this
  holds exactly across every switch;
* at least one worker was rekeyed, every rekeyed worker's epoch sizes
  strictly decrease (the controller only downshifts), its final codec
  is its last epoch's, and every worker that announced a
  `--bandwidth-mbps` hint was among the rekeyed;
* downlink accounting absorbs the control frames: a Rekey frame is
  `25 + len(spec)` B on the wire, so `broadcast_frames == steps + 1 +
  rekeys` and the clean-run byte formula gains the rekey frame bytes.

Tree mode (`--edge report.json`, repeatable): the root's "workers" are
edge leaders forwarding `UpdatePartial` frames. Each `--edge` file is a
`qafel leader --upstream` report; the check additionally asserts:

* every root ingest was a partial (`partials == uploads` per root row);
* per-edge byte accounting is exact at both hops: downstream
  `update_bytes` sums the edge's worker rows, upstream `partial_bytes
  == partials * expected_bytes_per_partial`;
* the edge buffer drained correctly: `updates == edge_buffer * partials
  + pending_at_shutdown`, with fewer than `edge_buffer` pending;
* the edge's replica followed every broadcast (`replica_t == steps`)
  and each downstream worker saw all broadcasts + Shutdown;
* cross-file: the root row for `edge_worker_id` took at most what that
  edge forwarded (a partial racing the Shutdown is legitimately
  dropped, never invented).

Usage:
  check_net_e2e.py report.json --steps N --workers N --codecs a,b,c
                   [--max-grad-ratio 0.9]
                   [--edge edge0.json --edge edge1.json --edge-buffer B]
"""

import argparse
import math
import sys

from checklib import Checker, load_json


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("report")
    ap.add_argument("--steps", type=int, required=True)
    ap.add_argument("--workers", type=int, required=True)
    ap.add_argument("--codecs", required=True, help="comma-separated expected codec multiset")
    ap.add_argument("--downlinks", default=None,
                    help="comma-separated expected downlink codec multiset (server_codec)")
    ap.add_argument("--max-grad-ratio", type=float, default=0.9)
    ap.add_argument("--adaptive", action="store_true",
                    help="run had net.adaptive enabled: per-epoch accounting, "
                         "--codecs matches join-time codecs, downshift asserted")
    ap.add_argument("--edge", action="append", default=[],
                    help="edge-leader report JSON (tree mode; one per root worker)")
    ap.add_argument("--edge-buffer", type=int, default=1,
                    help="net.edge_buffer the edges ran with (tree mode)")
    args = ap.parse_args()

    checker = Checker(args.report)
    check = checker.check
    doc, problem = load_json(args.report)
    if problem:
        checker.fail(problem)
        return checker.finish()
    tree_mode = bool(args.edge)

    check(doc.get("server_steps") == args.steps,
          f"server_steps {doc.get('server_steps')} != {args.steps}")
    check(doc.get("broadcasts") == args.steps,
          f"broadcasts {doc.get('broadcasts')} != {args.steps}")
    ratio = doc.get("grad_ratio")
    check(isinstance(ratio, (int, float)) and math.isfinite(ratio),
          f"grad_ratio missing or non-finite: {ratio!r}")
    if isinstance(ratio, (int, float)) and math.isfinite(ratio):
        check(ratio < args.max_grad_ratio,
              f"run did not converge: grad_ratio {ratio} >= {args.max_grad_ratio}")

    workers = doc.get("workers")
    check(isinstance(workers, list) and len(workers) == args.workers,
          f"expected {args.workers} worker entries, got "
          f"{len(workers) if isinstance(workers, list) else workers!r}")
    workers = workers if isinstance(workers, list) else []

    def join_codec(w):
        """The codec the worker joined on (epoch 0); its `codec` field
        tracks the current post-Rekey codec."""
        eps = w.get("epochs") or []
        return eps[0].get("codec", "?") if eps else w.get("codec", "?")

    got_codecs = sorted(join_codec(w) if args.adaptive else w.get("codec", "?")
                        for w in workers)
    want_codecs = sorted(args.codecs.split(","))
    check(got_codecs == want_codecs,
          f"negotiated codecs {got_codecs} != requested {want_codecs}")
    if args.downlinks is not None:
        got_down = sorted(w.get("server_codec", "?") for w in workers)
        want_down = sorted(args.downlinks.split(","))
        check(got_down == want_down,
              f"negotiated downlink codecs {got_down} != requested {want_down}")

    total_uploads = 0
    total_bytes = 0
    for w in workers:
        wid = w.get("worker_id")
        check(w.get("protocol") == 2, f"worker {wid}: protocol {w.get('protocol')} != 2")
        uploads = w.get("uploads", 0)
        check(uploads > 0, f"worker {wid}: never uploaded")
        expected = w.get("expected_bytes_per_upload", 0)
        check(expected > 0, f"worker {wid}: bad expected_bytes_per_upload {expected!r}")
        rekeys = w.get("rekeys", 0) if args.adaptive else 0
        if args.adaptive:
            # exact accounting per codec epoch: the join codec's epoch,
            # then one per Rekey. In-flight old-codec uploads accepted
            # during a transition window land in their own epoch, so
            # every epoch satisfies the wire-size formula exactly.
            eps = w.get("epochs") or []
            check(bool(eps), f"worker {wid}: no codec epochs in an adaptive run")
            check(len(eps) == rekeys + 1,
                  f"worker {wid}: {len(eps)} epochs != {rekeys} rekeys + 1")
            for e in eps:
                e_expected = e.get("expected_bytes_per_upload", 0)
                check(e_expected > 0,
                      f"worker {wid} epoch '{e.get('codec')}': bad "
                      f"expected_bytes_per_upload {e_expected!r}")
                check(e.get("upload_bytes") == e.get("uploads", 0) * e_expected,
                      f"worker {wid} epoch '{e.get('codec')}': upload_bytes "
                      f"{e.get('upload_bytes')} != {e.get('uploads')} uploads x "
                      f"{e_expected} B")
            check(sum(e.get("uploads", 0) for e in eps) == uploads,
                  f"worker {wid}: epoch uploads do not sum to {uploads}")
            check(sum(e.get("upload_bytes", 0) for e in eps) == w.get("upload_bytes"),
                  f"worker {wid}: epoch bytes do not sum to {w.get('upload_bytes')}")
            if rekeys:
                sizes = [e.get("expected_bytes_per_upload", 0) for e in eps]
                check(all(a > b for a, b in zip(sizes, sizes[1:])),
                      f"worker {wid}: epoch sizes not strictly decreasing: {sizes} "
                      f"(the controller only downshifts)")
                check(w.get("codec") == eps[-1].get("codec"),
                      f"worker {wid}: final codec {w.get('codec')} != last epoch "
                      f"{eps[-1].get('codec')}")
        else:
            check(w.get("upload_bytes") == uploads * expected,
                  f"worker {wid} ({w.get('codec')}): upload_bytes {w.get('upload_bytes')} != "
                  f"{uploads} uploads x {expected} B")
        if tree_mode:
            check(w.get("partials") == uploads,
                  f"worker {wid}: {w.get('partials')} partials != {uploads} uploads "
                  f"(tree roots must only ingest UpdatePartial frames)")
        else:
            check(w.get("partials", 0) == 0,
                  f"worker {wid}: unexpected partials {w.get('partials')} in a flat run")
        # downlink accounting: Broadcast frame = payload + 18 B, Shutdown
        # frame = 5 B, Sync frame = 17 B + 4 B/coordinate. Catch-up
        # increments replay the exact evicted payloads, so unless a fold
        # shipped a full-state Sync both formulas hold exactly even for
        # a throttled worker under a broadcast budget.
        down = w.get("expected_bytes_per_download", 0)
        check(down > 0, f"worker {wid}: bad expected_bytes_per_download {down!r}")
        skipped = w.get("skipped_broadcasts", 0)
        folds = w.get("catch_up_frames", 0)
        syncs = w.get("full_syncs", 0)
        # a Rekey frame is 21 B + the spec string, +4 B length prefix;
        # the writer counts it like any other control frame
        rekey_wire = sum(25 + len(e.get("codec", ""))
                         for e in (w.get("epochs") or [])[1:]) if args.adaptive else 0
        clean_frames = args.steps + 1 + rekeys
        clean_bytes = args.steps * (down + 18) + 5 + rekey_wire
        if syncs == 0:
            check(w.get("broadcast_frames") == clean_frames,
                  f"worker {wid}: broadcast_frames {w.get('broadcast_frames')} "
                  f"!= {clean_frames}")
            check(w.get("broadcast_bytes") == clean_bytes,
                  f"worker {wid} ({w.get('server_codec')}): broadcast_bytes "
                  f"{w.get('broadcast_bytes')} != {args.steps} x ({down} + 18) + 5 "
                  f"+ {rekey_wire} rekey B")
        else:
            # full-state syncs compress runs of steps into one frame
            check(w.get("broadcast_frames") <= clean_frames,
                  f"worker {wid}: broadcast_frames {w.get('broadcast_frames')} "
                  f"> {clean_frames} despite {syncs} full syncs")
            sync_frame = 17 + 4 * doc.get("d", 0)
            check(w.get("broadcast_bytes") <= clean_bytes + syncs * sync_frame,
                  f"worker {wid}: broadcast_bytes {w.get('broadcast_bytes')} exceeds "
                  f"{clean_bytes} + {syncs} x {sync_frame}")
        # skipped frames are always folded into a catch-up, never dropped
        check(skipped == 0 or folds > 0,
              f"worker {wid}: {skipped} skipped broadcasts but no catch-up frames")
        check(folds == 0 or skipped > 0,
              f"worker {wid}: {folds} catch-up frames without any skipped broadcast")
        check(syncs == 0 or folds > 0,
              f"worker {wid}: {syncs} full syncs without any catch-up frame")
        total_uploads += uploads
        total_bytes += w.get("upload_bytes", 0)
    check(total_uploads == doc.get("uploads"),
          f"per-worker uploads {total_uploads} != server total {doc.get('uploads')}")
    check(total_bytes == doc.get("upload_bytes"),
          f"per-worker bytes {total_bytes} != server total {doc.get('upload_bytes')}")

    if args.adaptive:
        rekeyed = [w for w in workers if w.get("rekeys", 0) > 0]
        check(bool(rekeyed), "adaptive run but no worker was ever rekeyed")
        hinted = [w for w in workers if w.get("bandwidth_hint") is not None]
        check(bool(hinted),
              "adaptive run but no worker announced a bandwidth hint "
              "(was --bandwidth-mbps passed to a worker?)")
        for w in hinted:
            check(w.get("rekeys", 0) > 0,
                  f"worker {w.get('worker_id')}: announced bandwidth hint "
                  f"{w.get('bandwidth_hint')} Mbit/s but was never rekeyed")

    # --- tree mode: per-edge accounting ------------------------------
    check(not tree_mode or len(args.edge) == args.workers,
          f"{len(args.edge)} --edge reports for {args.workers} root workers")
    root_rows = {w.get("worker_id"): w for w in workers}
    for path in args.edge:
        edoc, problem = load_json(path)
        if problem:
            checker.fail(f"{path}: {problem}")
            continue
        eid = edoc.get("edge_worker_id")
        tag = f"edge {eid} ({path})"

        updates = edoc.get("updates", 0)
        partials = edoc.get("partials", 0)
        pending = edoc.get("pending_at_shutdown", 0)
        check(updates > 0, f"{tag}: never ingested a downstream update")
        check(partials > 0, f"{tag}: never forwarded a partial")
        check(updates == args.edge_buffer * partials + pending,
              f"{tag}: {updates} updates != {args.edge_buffer} x {partials} partials "
              f"+ {pending} pending")
        check(0 <= pending < args.edge_buffer,
              f"{tag}: pending_at_shutdown {pending} outside [0, {args.edge_buffer})")
        expected_p = edoc.get("expected_bytes_per_partial", 0)
        check(expected_p > 0, f"{tag}: bad expected_bytes_per_partial {expected_p!r}")
        check(edoc.get("partial_bytes") == partials * expected_p,
              f"{tag}: partial_bytes {edoc.get('partial_bytes')} != "
              f"{partials} partials x {expected_p} B")
        check(edoc.get("replica_t") == args.steps,
              f"{tag}: replica_t {edoc.get('replica_t')} != {args.steps}")

        eworkers = edoc.get("workers")
        check(isinstance(eworkers, list) and eworkers, f"{tag}: no downstream worker rows")
        eworkers = eworkers if isinstance(eworkers, list) else []
        down_uploads = sum(w.get("uploads", 0) for w in eworkers)
        down_bytes = sum(w.get("upload_bytes", 0) for w in eworkers)
        check(down_uploads == updates,
              f"{tag}: downstream rows sum to {down_uploads} uploads, edge ingested {updates}")
        check(down_bytes == edoc.get("update_bytes"),
              f"{tag}: downstream rows sum to {down_bytes} B, edge counted "
              f"{edoc.get('update_bytes')}")
        for w in eworkers:
            wid = f"{tag} worker {w.get('worker_id')}"
            check(w.get("protocol") == 2, f"{wid}: protocol {w.get('protocol')} != 2")
            check(w.get("uploads", 0) > 0, f"{wid}: never uploaded")
            check(w.get("broadcast_frames") == args.steps + 1,
                  f"{wid}: broadcast_frames {w.get('broadcast_frames')} != {args.steps + 1}")

        row = root_rows.get(eid)
        check(row is not None, f"{tag}: no root worker row with id {eid}")
        if row is not None:
            # a partial forwarded while the Shutdown is in flight is
            # dropped at the root, so forwarded >= ingested, never <
            check(partials >= row.get("uploads", 0),
                  f"{tag}: forwarded {partials} partials but the root ingested "
                  f"{row.get('uploads')}")
            check(expected_p == row.get("expected_bytes_per_upload"),
                  f"{tag}: partial wire size {expected_p} != root's "
                  f"{row.get('expected_bytes_per_upload')}")

    shape = f"{len(args.edge)}-edge tree" if tree_mode else "flat"
    if args.adaptive:
        shape += f", {sum(w.get('rekeys', 0) for w in workers)} rekeys"
    ratio_s = f"{ratio:.4f}" if isinstance(ratio, (int, float)) else repr(ratio)
    return checker.finish(
        f"{shape}, {args.workers} workers, {args.steps} steps, "
        f"codecs {', '.join(want_codecs)}, grad_ratio {ratio_s}"
    )


if __name__ == "__main__":
    sys.exit(main())
