//! Vendored, offline subset of the `anyhow` error-handling API.
//!
//! The build environment has no crates.io access, so this workspace member
//! provides the exact surface the `qafel` crate uses under the same crate
//! name: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`]
//! macros, and the [`Context`] extension trait for `Result` and `Option`.
//!
//! Semantics mirror upstream `anyhow` where it matters here:
//! * `Error` is a cheap, heap-boxed, `Send + Sync + 'static` wrapper that
//!   can be built from any `std::error::Error` (enabling `?` conversions)
//!   or from a formatted message;
//! * `{}` displays the outermost message, `{:#}` appends the cause chain
//!   (`outer: cause1: cause2`), `{:?}` shows the message plus an indented
//!   `Caused by:` list;
//! * `.context(..)` / `.with_context(..)` wrap an existing error as the
//!   cause of a new message.

use std::error::Error as StdError;
use std::fmt;

/// A boxed, context-carrying error (subset of `anyhow::Error`).
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

/// `Result<T, anyhow::Error>` alias, as in upstream anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build from a displayable message with no underlying cause.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Build from any standard error (becomes both message and cause).
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Wrap this error as the cause of a new contextual message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(ChainedError(self))) }
    }

    /// The cause chain, outermost first (excluding this message).
    pub fn chain(&self) -> Chain<'_> {
        let next = self.source.as_deref().map(|e| {
            // coercion dropping the Send + Sync auto bounds
            let e: &(dyn StdError + 'static) = e;
            e
        });
        Chain { next }
    }

    /// The innermost error in the chain (self's message if no cause).
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut root: Option<&(dyn StdError + 'static)> = None;
        for e in self.chain() {
            root = Some(e);
        }
        root.unwrap_or(&NoCause)
    }
}

/// Iterator over an error's cause chain.
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);
    fn next(&mut self) -> Option<Self::Item> {
        let cur = self.next.take()?;
        self.next = cur.source();
        Some(cur)
    }
}

#[derive(Debug)]
struct NoCause;
impl fmt::Display for NoCause {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("(no cause)")
    }
}
impl StdError for NoCause {}

/// Adapter so an [`Error`] can serve as the `source()` of another
/// [`Error`] (upstream anyhow does this internally; `Error` itself must
/// not implement `std::error::Error` or the blanket `From` below would
/// conflict).
struct ChainedError(Error);

impl fmt::Debug for ChainedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.0, f)
    }
}
impl fmt::Display for ChainedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0.msg)
    }
}
impl StdError for ChainedError {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.0.source.as_deref().map(|e| {
            let e: &(dyn StdError + 'static) = e;
            e
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            for cause in self.chain() {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let causes: Vec<String> = self.chain().map(|c| c.to_string()).collect();
        if !causes.is_empty() {
            f.write_str("\n\nCaused by:")?;
            for (i, c) in causes.iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option` (subset of `anyhow::Context`).
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (subset of
/// `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with a formatted [`Error`] (subset of `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("file missing"));
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        let alt = format!("{e:#}");
        assert!(alt.starts_with("opening config: "), "{alt}");
        assert!(alt.contains("file missing"), "{alt}");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn with_context_on_option() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
        assert_eq!(Some(3u32).context("x").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed (got {x})");
            }
            ensure!(x < 10, "too big: {x}");
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(0).unwrap_err().to_string().contains("zero"));
        assert!(f(20).unwrap_err().to_string().contains("too big: 20"));
        let e = anyhow!("plain {} message", 1);
        assert_eq!(e.to_string(), "plain 1 message");
    }

    #[test]
    fn chain_and_root_cause() {
        let e = Error::new(io_err()).context("layer 1").context("layer 2");
        // chain: the "layer 1" wrapper, the message-level view of the
        // original Error, then the io error it was built from
        let msgs: Vec<String> = e.chain().map(|c| c.to_string()).collect();
        assert_eq!(msgs, vec!["layer 1", "file missing", "file missing"]);
        assert_eq!(e.root_cause().to_string(), "file missing");
    }
}
