"""L1 Pallas matmul kernel vs pure-jnp oracle (ref.matmul_ref).

hypothesis sweeps arbitrary (m, k, n) shapes — including sizes that are
not multiples of the block shape — and several block configurations.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import matmul, matmul_pallas
from compile.kernels.ref import matmul_ref


def _rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             dtype=jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 96),
    n=st.integers(1, 150),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_arbitrary_shapes(m, k, n, seed):
    a = _rand((m, k), seed)
    b = _rand((k, n), seed + 1)
    out = matmul_pallas(a, b)
    ref = matmul_ref(a, b)
    np.testing.assert_allclose(np.array(out), np.array(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8), (128, 128, 128),
                                      (64, 16, 32)])
def test_matmul_block_shapes(bm, bn, bk):
    a = _rand((70, 45), 0)
    b = _rand((45, 33), 1)
    out = matmul_pallas(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(np.array(out), np.array(matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


def test_matmul_exact_block_multiple():
    a = _rand((256, 128), 2)
    b = _rand((128, 256), 3)
    out = matmul_pallas(a, b)
    np.testing.assert_allclose(np.array(out), np.array(matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


def test_matmul_gradients_match_ref():
    a = _rand((37, 29), 4)
    b = _rand((29, 11), 5)

    def loss_kernel(a, b):
        return jnp.sum(jnp.tanh(matmul(a, b)))

    def loss_ref(a, b):
        return jnp.sum(jnp.tanh(matmul_ref(a, b)))

    ga, gb = jax.grad(loss_kernel, argnums=(0, 1))(a, b)
    ra, rb = jax.grad(loss_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.array(ga), np.array(ra), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.array(gb), np.array(rb), rtol=1e-4, atol=1e-5)


def test_matmul_zero_and_identity():
    a = _rand((16, 16), 6)
    eye = jnp.eye(16, dtype=jnp.float32)
    np.testing.assert_allclose(np.array(matmul_pallas(a, eye)), np.array(a),
                               rtol=1e-6, atol=1e-6)
    z = jnp.zeros((16, 16), dtype=jnp.float32)
    np.testing.assert_array_equal(np.array(matmul_pallas(a, z)),
                                  np.zeros((16, 16), np.float32))


def test_matmul_shape_mismatch_raises():
    with pytest.raises(ValueError):
        matmul_pallas(_rand((4, 5), 0), _rand((6, 4), 1))
