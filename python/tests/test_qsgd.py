"""L1 Pallas qsgd kernel: exact agreement with the oracle + the paper's
statistical properties (Definition 2.1 / Example B.1 / Lemma 3.1 of
Alistarh et al. 2017).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.qsgd import qsgd_dequantize, qsgd_quantize
from compile.kernels.ref import qsgd_dequantize_ref, qsgd_quantize_ref


def _xu(d, seed, scale=1.0):
    kx, ku = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (d,), dtype=jnp.float32) * scale
    u = jax.random.uniform(ku, (d,), dtype=jnp.float32)
    return x, u


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(1, 40000),
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qsgd_kernel_matches_ref(d, bits, seed):
    x, u = _xu(d, seed)
    s = jnp.float32(2 ** (bits - 1) - 1)
    lv, nrms = qsgd_quantize(x, u, s)
    lv_r, nrms_r = qsgd_quantize_ref(x, u, s)
    np.testing.assert_array_equal(np.array(lv), np.array(lv_r))
    np.testing.assert_allclose(np.array(nrms), np.array(nrms_r), rtol=1e-6)
    assert nrms.shape[0] == (d + 127) // 128


def test_qsgd_dequantize_matches_ref():
    x, u = _xu(4097, 7)
    s = jnp.float32(15.0)
    lv, nrms = qsgd_quantize(x, u, s)
    xq = qsgd_dequantize(lv, nrms, s)
    xr = qsgd_dequantize_ref(lv, nrms, s)
    np.testing.assert_allclose(np.array(xq), np.array(xr), rtol=1e-6)


def test_qsgd_levels_bounded():
    """xi_i <= ceil(|x_i| s / ||x||) <= s for any coordinate."""
    for bits in (2, 4, 8):
        s = 2 ** (bits - 1) - 1
        x, u = _xu(2048, bits)
        lv, _ = qsgd_quantize(x, u, jnp.float32(s))
        assert int(jnp.abs(lv).max()) <= s


def test_qsgd_unbiased():
    """E_u[Q(x)] = x: average reconstruction over many noise draws."""
    d = 512
    x = jax.random.normal(jax.random.PRNGKey(0), (d,), dtype=jnp.float32)
    s = jnp.float32(7.0)
    reps = 300
    acc = np.zeros(d, np.float64)
    for r in range(reps):
        u = jax.random.uniform(jax.random.PRNGKey(1000 + r), (d,))
        lv, nrms = qsgd_quantize(x, u, s)
        acc += np.array(qsgd_dequantize(lv, nrms, s), np.float64)
    mean = acc / reps
    err = np.linalg.norm(mean - np.array(x)) / np.linalg.norm(np.array(x))
    # statistical tolerance: the variance of the mean estimate is bounded by
    # min(2d/s^2, sqrt(2d)/s) ||x||^2 / reps (Lemma 3.1); allow 3 sigma.
    tol = 3.0 * np.sqrt(min(2 * 128 / float(s) ** 2,
                            np.sqrt(2 * 128) / float(s)) / reps)
    assert err < tol, f"bias too large: {err} (tol {tol})"


def test_qsgd_variance_bound():
    """E||Q(x)-x||^2 <= min(2g/s^2, sqrt(2g)/s) ||x||^2 per bucket of
    size g (Lemma 3.1, Alistarh et al. 2017, bucketed)."""
    d, s, g = 8192, 15.0, 128
    bound = min(2 * g / s**2, np.sqrt(2 * g) / s)
    x = jax.random.normal(jax.random.PRNGKey(3), (d,), dtype=jnp.float32)
    xn = float(jnp.sum(x * x))
    errs = []
    for r in range(40):
        u = jax.random.uniform(jax.random.PRNGKey(5000 + r), (d,))
        lv, nrms = qsgd_quantize(x, u, jnp.float32(s))
        xq = qsgd_dequantize(lv, nrms, jnp.float32(s))
        errs.append(float(jnp.sum((xq - x) ** 2)))
    mean_err = np.mean(errs)
    assert mean_err <= bound * xn * 1.05, (mean_err, bound * xn)


def test_qsgd_zero_vector():
    d = 100
    lv, nrms = qsgd_quantize(jnp.zeros(d), jnp.full(d, 0.5), jnp.float32(7.0))
    assert float(np.abs(np.array(nrms)).max()) == 0.0
    np.testing.assert_array_equal(np.array(lv), np.zeros(d, np.int32))


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(1e-6, 1e6), seed=st.integers(0, 1000))
def test_qsgd_scale_invariance_of_levels(scale, seed):
    """Levels depend on x/||x|| only: scaling x leaves levels unchanged."""
    x, u = _xu(777, seed)
    s = jnp.float32(7.0)
    lv1, _ = qsgd_quantize(x, u, s)
    lv2, _ = qsgd_quantize(x * scale, u, s)
    np.testing.assert_array_equal(np.array(lv1), np.array(lv2))
