"""L2 model tests: parameter layout, shapes, gradient correctness
(finite differences), training dynamics, and client_update semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

SMALL = M.ModelConfig(channels=8, n_layers=2, groups=2, dropout=0.0)
PAPER = M.ModelConfig()


def _batch(cfg, b, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (b, cfg.height, cfg.width, cfg.in_channels))
    y = (jax.random.uniform(ky, (b,)) > 0.5).astype(jnp.int32)
    return x, y, jnp.ones((b,), jnp.float32)


def test_param_count_matches_paper_scale():
    """Paper: 117.128 kB full-precision update => d = 29,282. Our faithful
    re-derivation of the architecture gives 29,474 (within 0.7%)."""
    d = M.num_params(PAPER)
    assert d == 29474
    assert abs(d - 29282) / 29282 < 0.01


def test_flatten_unflatten_roundtrip():
    flat = M.init_params(SMALL, jnp.int32(0))
    params = M.unflatten(SMALL, flat)
    flat2 = M.flatten(SMALL, params)
    np.testing.assert_array_equal(np.array(flat), np.array(flat2))
    # every declared layer is present with the declared shape
    for name, shape in M.param_spec(SMALL):
        assert params[name].shape == shape


def test_init_params_structure():
    flat = M.init_params(SMALL, jnp.int32(42))
    p = M.unflatten(SMALL, flat)
    np.testing.assert_array_equal(np.array(p["gn0/scale"]),
                                  np.ones(SMALL.channels, np.float32))
    np.testing.assert_array_equal(np.array(p["conv0/b"]),
                                  np.zeros(SMALL.channels, np.float32))
    assert float(jnp.abs(p["conv0/w"]).max()) > 0


def test_init_params_deterministic_and_seed_sensitive():
    a = M.init_params(SMALL, jnp.int32(1))
    b = M.init_params(SMALL, jnp.int32(1))
    c = M.init_params(SMALL, jnp.int32(2))
    np.testing.assert_array_equal(np.array(a), np.array(b))
    assert not np.array_equal(np.array(a), np.array(c))


def test_forward_shapes():
    flat = M.init_params(SMALL, jnp.int32(0))
    x, _, _ = _batch(SMALL, 5)
    logits = M.forward(SMALL, flat, x, False, jax.random.PRNGKey(0))
    assert logits.shape == (5, SMALL.classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_gradient_matches_finite_difference():
    cfg = M.ModelConfig(channels=4, n_layers=1, groups=2, dropout=0.0)
    flat = M.init_params(cfg, jnp.int32(0))
    x, y, mask = _batch(cfg, 3)

    def loss(f):
        return M._loss_acc(cfg, f, x, y, mask, False,
                           jax.random.PRNGKey(0))[0]

    g = jax.grad(loss)(flat)
    # check a spread of coordinates with central differences
    rng = np.random.RandomState(0)
    idxs = rng.choice(flat.shape[0], 12, replace=False)
    eps = 1e-3
    for i in idxs:
        e = jnp.zeros_like(flat).at[i].set(eps)
        num = (loss(flat + e) - loss(flat - e)) / (2 * eps)
        assert abs(float(num) - float(g[i])) < 5e-3, (i, float(num), float(g[i]))


def test_train_step_reduces_loss_on_fixed_batch():
    flat = M.init_params(SMALL, jnp.int32(0))
    x, y, mask = _batch(SMALL, 16)
    lr = jnp.float32(0.05)
    losses = []
    for i in range(30):
        flat, loss, _ = M.train_step(SMALL, flat, x, y, mask, lr,
                                     jnp.int32(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_client_update_equals_sequential_steps_when_no_dropout():
    """With dropout=0, client_update(P) == P chained train_steps."""
    cfg = SMALL
    flat0 = M.init_params(cfg, jnp.int32(0))
    p_steps, b = 3, 4
    xs = jnp.stack([_batch(cfg, b, seed=s)[0] for s in range(p_steps)])
    ys = jnp.stack([_batch(cfg, b, seed=s)[1] for s in range(p_steps)])
    ms = jnp.ones((p_steps, b), jnp.float32)
    lr = jnp.float32(0.01)
    delta, _, _ = M.client_update(cfg, flat0, xs, ys, ms, lr, jnp.int32(9))
    flat = flat0
    for p in range(p_steps):
        key = jax.random.fold_in(jax.random.PRNGKey(9), p)
        (_, _), grads = jax.value_and_grad(
            lambda f: M._loss_acc(cfg, f, xs[p], ys[p], ms[p], True, key),
            has_aux=True)(flat)
        flat = flat - lr * grads
    np.testing.assert_allclose(np.array(delta), np.array(flat - flat0),
                               rtol=1e-5, atol=1e-6)


def test_client_update_mask_ignores_padded_samples():
    """Padded (mask=0) samples must not change the update."""
    cfg = SMALL
    flat = M.init_params(cfg, jnp.int32(0))
    x, y, _ = _batch(cfg, 8)
    m_full = jnp.array([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
    # corrupt the padded tail; result must be identical
    x2 = x.at[4:].set(999.0)
    d1, _, _ = M.client_update(cfg, flat, x[None], y[None], m_full[None],
                               jnp.float32(0.01), jnp.int32(0))
    d2, _, _ = M.client_update(cfg, flat, x2[None], y[None], m_full[None],
                               jnp.float32(0.01), jnp.int32(0))
    np.testing.assert_allclose(np.array(d1), np.array(d2), atol=1e-6)


def test_eval_step_counts():
    flat = M.init_params(SMALL, jnp.int32(0))
    x, y, _ = _batch(SMALL, 10)
    mask = jnp.array([1] * 6 + [0] * 4, jnp.float32)
    loss_sum, correct, count = M.eval_step(SMALL, flat, x, y, mask)
    assert float(count) == 6.0
    assert 0.0 <= float(correct) <= 6.0
    assert float(loss_sum) > 0.0


def test_dropout_changes_with_seed_only_in_train_mode():
    cfg = dataclasses.replace(SMALL, dropout=0.5)
    flat = M.init_params(cfg, jnp.int32(0))
    x, y, mask = _batch(cfg, 8)
    lr = jnp.float32(0.01)
    p1, _, _ = M.train_step(cfg, flat, x, y, mask, lr, jnp.int32(1))
    p2, _, _ = M.train_step(cfg, flat, x, y, mask, lr, jnp.int32(2))
    assert not np.allclose(np.array(p1), np.array(p2))
    # eval ignores dropout entirely: deterministic
    e1 = M.eval_step(cfg, flat, x, y, mask)
    e2 = M.eval_step(cfg, flat, x, y, mask)
    assert float(e1[0]) == float(e2[0])


@pytest.mark.parametrize("b", [1, 3, 32])
def test_batch_size_independence_of_shapes(b):
    flat = M.init_params(SMALL, jnp.int32(0))
    x, y, mask = _batch(SMALL, b)
    p2, loss, acc = M.train_step(SMALL, flat, x, y, mask, jnp.float32(0.01),
                                 jnp.int32(0))
    assert p2.shape == flat.shape
    assert np.isfinite(float(loss)) and 0.0 <= float(acc) <= 1.0
