"""AOT pipeline tests: every exported computation lowers to parseable HLO
text with the signature recorded in the manifest."""

import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def small_artifacts(tmp_path_factory):
    """Build a small-config artifact set once for the module."""
    out = tmp_path_factory.mktemp("artifacts")
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--channels", "8", "--layers", "2", "--batch", "4",
         "--local-steps", "2", "--eval-batch", "8"],
        check=True, cwd=os.path.dirname(os.path.dirname(__file__)), env=env)
    return out


def test_manifest_complete(small_artifacts):
    man = json.loads((small_artifacts / "manifest.json").read_text())
    assert man["format"] == "qafel-artifacts-v1"
    d = man["model"]["d"]
    # layer layout covers the whole vector exactly
    end = 0
    for layer in man["model"]["layers"]:
        assert layer["offset"] == end
        end += layer["size"]
    assert end == d
    for name in ["init_params", "train_step", "client_update",
                 "client_update_quantized", "eval_step", "qsgd_quantize"]:
        assert name in man["artifacts"], name
        f = small_artifacts / man["artifacts"][name]["file"]
        assert f.exists() and f.stat().st_size > 0


def test_hlo_text_header(small_artifacts):
    man = json.loads((small_artifacts / "manifest.json").read_text())
    for name, art in man["artifacts"].items():
        text = (small_artifacts / art["file"]).read_text()
        assert text.startswith("HloModule"), f"{name} not HLO text"
        assert "ENTRY" in text


def test_manifest_signatures_match_model(small_artifacts):
    man = json.loads((small_artifacts / "manifest.json").read_text())
    d = man["model"]["d"]
    cu = man["artifacts"]["client_update"]
    assert cu["inputs"][0]["shape"] == [d]
    assert cu["inputs"][1]["shape"][:2] == [2, 4]  # [P, B, H, W, C]
    assert cu["outputs"][0]["shape"] == [d]
    ev = man["artifacts"]["eval_step"]
    assert ev["inputs"][1]["shape"][0] == 8


def test_to_hlo_text_roundtrip_numeric():
    """Lower a tiny fn and re-execute the HLO via jax's own client to make
    sure text emission didn't change semantics."""
    fn = lambda x: (x * 2.0 + 1.0,)
    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # jax's CPU backend can compile HLO text back
    from jax._src.lib import xla_client as xc
    # parse check only (execution via rust is covered by cargo tests)
    assert "ENTRY" in text and "f32[4]" in text


def test_default_config_d_value():
    assert M.num_params(M.ModelConfig()) == 29474
