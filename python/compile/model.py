"""L2: the paper's model + training computation in JAX (build-time only).

The model is the LEAF CelebA CNN as configured by FedBuff / QAFeL (§4 and
Appendix D of the paper): a four-layer CNN binary classifier with stride 1,
padding 2, dropout 0.1, and GroupNorm instead of BatchNorm (Wu & He 2018,
per the FedBuff experimental setup). Input is 32x32x3; each block is
conv3x3(32) -> GroupNorm -> ReLU -> maxpool2. The classifier head is a
dense layer to 2 logits, computed with the L1 Pallas matmul kernel so that
the Pallas kernel lowers into the same HLO as the rest of the model (and
into its backward pass via the kernel's custom VJP).

Everything here operates on a FLAT f32[d] parameter vector: the rust
coordinator, quantizers, and wire codecs treat the model as an opaque
vector, exactly as the algorithm in the paper does. Flatten/unflatten
happen inside the jitted functions.

Exported computations (lowered to HLO text by aot.py):
  init_params    seed                                    -> params[d]
  train_step     params, x, y, mask, lr, seed            -> params', loss, acc
  client_update  params, xs[P,...], ys, masks, lr, seed  -> delta[d], loss, acc
  eval_step      params, x, y, mask                      -> loss_sum, correct, count
  qsgd_quantize  x[d], u[d], s                           -> levels[d], norm

The paper's sign convention: Algorithm 2 computes P local SGD steps from
the hidden state y_0 = x_hat and uploads the quantized model difference;
the server applies x^{t+1} = x^t + eta_g * mean(delta). We define
delta := y_P - y_0 (the descent direction), matching §2's description and
making the server update a descent step.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul
from .kernels.qsgd import qsgd_quantize


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (defaults = paper's CelebA model)."""
    height: int = 32
    width: int = 32
    in_channels: int = 3
    channels: int = 32
    n_layers: int = 4
    kernel: int = 3
    padding: int = 2        # paper: "a padding of 2"
    stride: int = 1         # paper: "a stride of 1"
    groups: int = 4         # GroupNorm groups over `channels`
    dropout: float = 0.1    # paper: "a dropout rate of 0.1"
    classes: int = 2        # smiling / not smiling

    def spatial_dims(self) -> List[Tuple[int, int]]:
        """(h, w) after each conv+pool block (conv grows by 2*pad - k + 1)."""
        h, w = self.height, self.width
        dims = []
        for _ in range(self.n_layers):
            h = h + 2 * self.padding - self.kernel + 1
            w = w + 2 * self.padding - self.kernel + 1
            h, w = h // 2, w // 2
            dims.append((h, w))
        return dims

    def feature_size(self) -> int:
        h, w = self.spatial_dims()[-1]
        return h * w * self.channels


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list defining the flat parameter layout."""
    spec: List[Tuple[str, Tuple[int, ...]]] = []
    c_in = cfg.in_channels
    for i in range(cfg.n_layers):
        spec.append((f"conv{i}/w", (cfg.kernel, cfg.kernel, c_in, cfg.channels)))
        spec.append((f"conv{i}/b", (cfg.channels,)))
        spec.append((f"gn{i}/scale", (cfg.channels,)))
        spec.append((f"gn{i}/bias", (cfg.channels,)))
        c_in = cfg.channels
    spec.append(("dense/w", (cfg.feature_size(), cfg.classes)))
    spec.append(("dense/b", (cfg.classes,)))
    return spec


def num_params(cfg: ModelConfig) -> int:
    total = 0
    for _, shape in param_spec(cfg):
        n = 1
        for s in shape:
            n *= s
        total += n
    return total


def unflatten(cfg: ModelConfig, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    params = {}
    off = 0
    for name, shape in param_spec(cfg):
        n = 1
        for s in shape:
            n *= s
        params[name] = flat[off:off + n].reshape(shape)
        off += n
    return params


def flatten(cfg: ModelConfig, params: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in param_spec(cfg)])


def init_params(cfg: ModelConfig, seed: jnp.ndarray) -> jnp.ndarray:
    """He-normal conv/dense weights, zero biases, unit GN scales."""
    key = jax.random.PRNGKey(seed)
    parts = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("/w"):
            fan_in = 1
            for s in shape[:-1]:
                fan_in *= s
            w = jax.random.normal(sub, shape) * jnp.sqrt(2.0 / fan_in)
            parts.append(w.reshape(-1))
        elif "/scale" in name:
            parts.append(jnp.ones(shape).reshape(-1))
        else:
            parts.append(jnp.zeros(shape).reshape(-1))
    return jnp.concatenate(parts).astype(jnp.float32)


def _group_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
                groups: int, eps: float = 1e-5) -> jnp.ndarray:
    """GroupNorm over (H, W, C/G) per group; x is NHWC."""
    b, h, w, c = x.shape
    xg = x.reshape(b, h, w, groups, c // groups)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return xg.reshape(b, h, w, c) * scale + bias


def _conv2d_im2col(x: jnp.ndarray, w: jnp.ndarray, pad: int,
                   mm=jnp.dot) -> jnp.ndarray:
    """conv2d as im2col + matmul (NHWC, stride 1).

    XLA's CPU backend lowers the *weight gradient* of
    `lax.conv_general_dilated` to a pathologically slow kernel (~3.4 s for
    a 3x3x32x32 grad at batch 32 on this testbed — measured in
    EXPERIMENTS.md §Perf). Expressing the conv as patch-matrix x
    weight-matrix makes both the forward and all gradients plain `dot`s
    (fast everywhere, and MXU-friendly on TPU). `mm` is pluggable so the
    L1 Pallas matmul kernel can own this hot-spot on real TPUs; on the
    CPU-interpret testbed the Pallas while-loop emulation is slower than
    the fused dot, so the default is `jnp.dot` (see DESIGN.md
    §Hardware-Adaptation).
    """
    kh, kw, cin, cout = w.shape
    b, h, wd, _ = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = h + 2 * pad - kh + 1
    ow = wd + 2 * pad - kw + 1
    cols = [xp[:, i:i + oh, j:j + ow, :] for i in range(kh) for j in range(kw)]
    patches = jnp.concatenate(cols, axis=-1)
    out = mm(patches.reshape(-1, kh * kw * cin), w.reshape(-1, cout))
    return out.reshape(b, oh, ow, cout)


def _max_pool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 max pool, stride 2, floor semantics (crop odd edges)."""
    b, h, w, c = x.shape
    h2, w2 = (h // 2) * 2, (w // 2) * 2
    x = x[:, :h2, :w2, :]
    return x.reshape(b, h2 // 2, 2, w2 // 2, 2, c).max(axis=(2, 4))


def forward(cfg: ModelConfig, flat: jnp.ndarray, x: jnp.ndarray,
            train: bool, dropout_key) -> jnp.ndarray:
    """Logits for a batch x[B,H,W,C] (NHWC, f32)."""
    p = unflatten(cfg, flat)
    h = x
    for i in range(cfg.n_layers):
        h = _conv2d_im2col(h, p[f"conv{i}/w"], cfg.padding) + p[f"conv{i}/b"]
        h = _group_norm(h, p[f"gn{i}/scale"], p[f"gn{i}/bias"], cfg.groups)
        h = jax.nn.relu(h)
        h = _max_pool2(h)
    feats = h.reshape(h.shape[0], -1)
    if train and cfg.dropout > 0.0:
        keep = 1.0 - cfg.dropout
        dmask = jax.random.bernoulli(dropout_key, keep, feats.shape)
        feats = feats * dmask / keep
    # Classifier head through the L1 Pallas matmul kernel.
    logits = matmul(feats, p["dense/w"]) + p["dense/b"]
    return logits


def _loss_acc(cfg: ModelConfig, flat, x, y, mask, train, dropout_key):
    """Masked mean cross-entropy + accuracy over a batch."""
    logits = forward(cfg, flat, x, train, dropout_key)
    logp = jax.nn.log_softmax(logits)
    y = y.astype(jnp.int32)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    pred = jnp.argmax(logits, axis=1).astype(jnp.int32)
    acc = ((pred == y).astype(jnp.float32) * mask).sum() / denom
    return loss, acc


def train_step(cfg: ModelConfig, flat, x, y, mask, lr, seed):
    """One local SGD step: params <- params - lr * grad (Algorithm 2 l.3)."""
    key = jax.random.PRNGKey(seed)
    (loss, acc), grads = jax.value_and_grad(
        lambda f: _loss_acc(cfg, f, x, y, mask, True, key), has_aux=True)(flat)
    return flat - lr * grads, loss, acc


def client_update(cfg: ModelConfig, flat, xs, ys, masks, lr, seed):
    """Algorithm 2: P local SGD steps from the hidden-state snapshot.

    xs: f32[P,B,H,W,C], ys: i32[P,B], masks: f32[P,B].
    Returns (delta[d] = y_P - y_0, mean loss, mean acc) over the P steps.
    One PJRT call executes the whole local round (lax.scan over P).
    """
    p_steps = xs.shape[0]

    def step(carry, inp):
        params, i = carry
        x, y, m = inp
        key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        (loss, acc), grads = jax.value_and_grad(
            lambda f: _loss_acc(cfg, f, x, y, m, True, key),
            has_aux=True)(params)
        return (params - lr * grads, i + 1), (loss, acc)

    (final, _), (losses, accs) = jax.lax.scan(
        step, (flat, jnp.int32(0)), (xs, ys, masks), length=p_steps)
    return final - flat, losses.mean(), accs.mean()


def client_update_quantized(cfg: ModelConfig, flat, xs, ys, masks, lr, seed,
                            u, s):
    """Algorithm 2 including the upload quantization: the L1 Pallas qsgd
    kernel quantizes the delta inside the same HLO module, so the full
    client request path is one executable."""
    delta, loss, acc = client_update(cfg, flat, xs, ys, masks, lr, seed)
    levels, norm = qsgd_quantize(delta, u, s)
    return levels, norm, loss, acc


def eval_step(cfg: ModelConfig, flat, x, y, mask):
    """Validation: summed loss / correct count / count (no dropout)."""
    logits = forward(cfg, flat, x, False, jax.random.PRNGKey(0))
    logp = jax.nn.log_softmax(logits)
    y = y.astype(jnp.int32)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    mask = mask.astype(jnp.float32)
    pred = jnp.argmax(logits, axis=1).astype(jnp.int32)
    correct = ((pred == y).astype(jnp.float32) * mask).sum()
    return (nll * mask).sum(), correct, mask.sum()


def build_fns(cfg: ModelConfig, batch: int, local_steps: int, eval_batch: int):
    """Concrete jittable entry points + their example argument shapes."""
    h, w, c = cfg.height, cfg.width, cfg.in_channels
    d = num_params(cfg)
    f32, i32 = jnp.float32, jnp.int32

    def sds(shape, dt=f32):
        return jax.ShapeDtypeStruct(shape, dt)

    fns = {
        "init_params": (
            functools.partial(init_params, cfg),
            [sds((), i32)],
        ),
        "train_step": (
            functools.partial(train_step, cfg),
            [sds((d,)), sds((batch, h, w, c)), sds((batch,), i32),
             sds((batch,)), sds(()), sds((), i32)],
        ),
        "client_update": (
            functools.partial(client_update, cfg),
            [sds((d,)), sds((local_steps, batch, h, w, c)),
             sds((local_steps, batch), i32), sds((local_steps, batch)),
             sds(()), sds((), i32)],
        ),
        "client_update_quantized": (
            functools.partial(client_update_quantized, cfg),
            [sds((d,)), sds((local_steps, batch, h, w, c)),
             sds((local_steps, batch), i32), sds((local_steps, batch)),
             sds(()), sds((), i32), sds((d,)), sds(())],
        ),
        "eval_step": (
            functools.partial(eval_step, cfg),
            [sds((d,)), sds((eval_batch, h, w, c)), sds((eval_batch,), i32),
             sds((eval_batch,))],
        ),
        "qsgd_quantize": (
            lambda x, u, s: qsgd_quantize(x, u, s),
            [sds((d,)), sds((d,)), sds(())],
        ),
    }
    return fns
