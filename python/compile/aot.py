"""AOT compile path: lower the L2/L1 computations to HLO text artifacts.

Runs ONCE at build time (`make artifacts`); python never appears on the
rust request path. The interchange format is HLO *text*, not a serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids that
the rust side's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs, under --out-dir (default ../artifacts):
  <name>.hlo.txt   one per exported computation (see model.build_fns)
  manifest.json    machine-readable description: model config, flat-param
                   layout, and the input/output signature of every
                   artifact. rust/src/runtime/manifest.rs parses this.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps a tuple, even for single outputs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(avals) -> list:
    out = []
    for a in avals:
        out.append({"dtype": str(a.dtype), "shape": list(a.shape)})
    return out


def build_manifest(cfg: M.ModelConfig, batch: int, local_steps: int,
                   eval_batch: int, artifacts: dict) -> dict:
    layers = []
    off = 0
    for name, shape in M.param_spec(cfg):
        n = 1
        for s in shape:
            n *= s
        layers.append({"name": name, "shape": list(shape),
                       "offset": off, "size": n})
        off += n
    return {
        "format": "qafel-artifacts-v1",
        "model": {**dataclasses.asdict(cfg), "d": off, "layers": layers},
        "train": {"batch": batch, "local_steps": local_steps},
        "eval_batch": eval_batch,
        "artifacts": artifacts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=32,
                    help="local SGD batch size (LEAF CelebA: 32)")
    ap.add_argument("--local-steps", type=int, default=1,
                    help="P local steps per client round (1 epoch over "
                         "<=32 samples at batch 32 -> P=1, as in the paper)")
    ap.add_argument("--eval-batch", type=int, default=256)
    ap.add_argument("--channels", type=int, default=32)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--only", default="",
                    help="comma-separated subset of artifact names")
    args = ap.parse_args()

    cfg = M.ModelConfig(channels=args.channels, n_layers=args.layers)
    os.makedirs(args.out_dir, exist_ok=True)
    fns = M.build_fns(cfg, args.batch, args.local_steps, args.eval_batch)
    only = set(filter(None, args.only.split(",")))

    artifacts = {}
    for name, (fn, avals) in fns.items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*avals)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *avals)
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        artifacts[name] = {
            "file": fname,
            "inputs": _sig(avals),
            "outputs": _sig(out_avals),
        }
        print(f"wrote {path} ({len(text) / 1e6:.2f} MB, "
              f"{len(avals)} in / {len(out_avals)} out)")

    manifest = build_manifest(cfg, args.batch, args.local_steps,
                              args.eval_batch, artifacts)
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} (d={manifest['model']['d']})")


if __name__ == "__main__":
    main()
