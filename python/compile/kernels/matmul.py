"""Tiled Pallas matmul kernel (L1).

The kernel tiles (M, N, K) into MXU-friendly blocks expressed with
`BlockSpec`s: the grid iterates (m, n, k); each step loads an
(bm, bk) A-tile and a (bk, bn) B-tile from HBM into VMEM and accumulates
into the (bm, bn) output tile, which Pallas keeps resident in VMEM across
the innermost k axis (revisiting semantics). This is the HBM<->VMEM
schedule a CUDA implementation would express with threadblocks + shared
memory; on TPU the inner `jnp.dot` maps onto the MXU systolic array.

On this testbed the kernel runs with interpret=True (the CPU PJRT plugin
cannot execute Mosaic custom-calls); correctness is validated against
ref.matmul_ref and the real-TPU efficiency is estimated from the block
shapes in DESIGN.md / EXPERIMENTS.md §Perf.

`matmul` is differentiable via a custom VJP whose backward pass reuses the
same Pallas kernel (dA = g @ B^T, dB = A^T @ g), so jax.grad through the
L2 model keeps the kernel in both the forward and backward HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shapes: 128x128 output tiles (MXU native 128x128) with a
# 128-deep K panel. f32[128,128] * 3 tiles = 192 KiB of VMEM, well under
# the ~16 MiB/core budget, leaving room for double buffering.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (m, n, k) grid step: o[m,n] += a[m,k] @ b[k,n]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=jnp.float32)


def _pad_to(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def _ceil_mul(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, *,
                  bm: int = BLOCK_M, bn: int = BLOCK_N, bk: int = BLOCK_K,
                  interpret: bool = True) -> jnp.ndarray:
    """f32 matmul via the tiled Pallas kernel. Shapes need not be aligned;
    inputs are zero-padded to block multiples and the result is cropped."""
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"matmul shape mismatch: {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    # Never use a block larger than the (padded) dimension itself.
    bm = min(bm, _ceil_mul(m, 8))
    bn = min(bn, _ceil_mul(n, 8))
    bk = min(bk, _ceil_mul(k, 8))
    mp, kp, np_ = _ceil_mul(m, bm), _ceil_mul(k, bk), _ceil_mul(n, bn)
    ap = _pad_to(a.astype(jnp.float32), mp, kp)
    bp = _pad_to(b.astype(jnp.float32), kp, np_)
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Differentiable Pallas matmul (kernel used in fwd AND bwd HLO)."""
    return matmul_pallas(a, b)


def _matmul_fwd(a, b):
    return matmul_pallas(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    da = matmul_pallas(g, b.T)
    db = matmul_pallas(a.T, g)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)
