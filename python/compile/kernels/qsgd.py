"""Pallas **bucketed** qsgd stochastic-quantization kernel (L1).

This is the paper's communication hot-spot: every client upload and every
server broadcast passes through qsgd_s (Example B.1). Following the
original QSGD design (Alistarh et al. 2017), the vector is quantized in
buckets of `bucket` coordinates with one l2 norm per bucket — the
variance constant becomes min(2g/s^2, sqrt(2g)/s) instead of growing
with the full dimension, which is what makes 4-bit quantization usable
at the paper's d = 29,474. The rust wire codec
(rust/src/quant/qsgd.rs) implements the identical math; integration
tests assert bit-identical levels.

The kernel performs the elementwise stochastic rounding
    xi_i = floor(|x_i| * s / ||bucket(i)|| + u_i),   u_i ~ U[0,1)
emitting signed integer levels in {-s..s}; the receiver reconstructs
||bucket|| / s * levels. Bucket norms are a cheap segmented reduction
computed with jnp before the kernel launch; the per-element scale vector
is an explicit kernel input, so each VMEM tile is (block_rows, 128)
aligned to the 8x128 VPU lanes. Uniform noise is an explicit input
(deterministic + testable; the rust coordinator owns all randomness).

interpret=True on this CPU testbed; validated against ref.qsgd_quantize_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-12
LANES = 128
# 256 rows x 128 lanes x 4 B = 128 KiB per input tile in VMEM.
BLOCK_ROWS = 256
# QSGD bucket size (must match rust quant::qsgd::DEFAULT_BUCKET).
BUCKET = 128


def _qsgd_kernel(x_ref, u_ref, scale_ref, out_ref):
    """Elementwise stochastic rounding on one (rows, 128) tile.

    scale_ref holds the precomputed per-element s / max(||bucket||, eps)
    so the kernel does a single multiply per element and no division.
    """
    x = x_ref[...]
    a = jnp.abs(x) * scale_ref[...]
    levels = jnp.floor(a + u_ref[...])
    out_ref[...] = (jnp.sign(x) * levels).astype(jnp.int32)


def _ceil_mul(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def bucket_norms(x: jnp.ndarray, bucket: int = BUCKET) -> jnp.ndarray:
    """Per-bucket l2 norms (last bucket may be partial; zero-padded)."""
    d = x.shape[0]
    dp = _ceil_mul(d, bucket)
    xp = jnp.pad(x, (0, dp - d))
    return jnp.sqrt(jnp.sum(xp.reshape(-1, bucket) ** 2, axis=1))


@functools.partial(jax.jit,
                   static_argnames=("bucket", "block_rows", "interpret"))
def qsgd_quantize(x: jnp.ndarray, u: jnp.ndarray, s: jnp.ndarray, *,
                  bucket: int = BUCKET, block_rows: int = BLOCK_ROWS,
                  interpret: bool = True):
    """Quantize f32[d] to signed qsgd levels with per-bucket norms.

    Args:
      x: f32[d] vector (client delta or server hidden-state diff).
      u: f32[d] U[0,1) noise.
      s: scalar f32 number of levels (2**(bits-1) - 1 for packed codecs).

    Returns:
      (levels i32[d], norms f32[ceil(d/bucket)]).
    """
    if x.shape != u.shape or x.ndim != 1:
        raise ValueError(f"qsgd shape mismatch: x={x.shape} u={u.shape}")
    d = x.shape[0]
    x = x.astype(jnp.float32)
    norms = bucket_norms(x, bucket)
    # per-element scale s / ||bucket(i)||
    scale = s / jnp.maximum(norms, EPS)
    scale_elem = jnp.repeat(scale, bucket)[:d]

    dp = _ceil_mul(d, block_rows * LANES)
    xp = jnp.pad(x, (0, dp - d)).reshape(-1, LANES)
    up = jnp.pad(u.astype(jnp.float32), (0, dp - d)).reshape(-1, LANES)
    sp = jnp.pad(scale_elem, (0, dp - d)).reshape(-1, LANES)
    rows = xp.shape[0]
    grid = (rows // block_rows,)
    out = pl.pallas_call(
        _qsgd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(xp, up, sp)
    return out.reshape(-1)[:d], norms


def _dequant_kernel(lv_ref, unit_ref, out_ref):
    out_ref[...] = lv_ref[...].astype(jnp.float32) * unit_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("bucket", "block_rows", "interpret"))
def qsgd_dequantize(levels: jnp.ndarray, norms: jnp.ndarray, s: jnp.ndarray,
                    *, bucket: int = BUCKET, block_rows: int = BLOCK_ROWS,
                    interpret: bool = True):
    """Reconstruct f32[d] = norms[bucket(i)] / s * levels (Pallas kernel)."""
    d = levels.shape[0]
    unit = norms / jnp.maximum(s, 1.0)
    unit_elem = jnp.repeat(unit, bucket)[:d]
    dp = _ceil_mul(d, block_rows * LANES)
    lp = jnp.pad(levels, (0, dp - d)).reshape(-1, LANES)
    upade = jnp.pad(unit_elem, (0, dp - d)).reshape(-1, LANES)
    rows = lp.shape[0]
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(lp, upade)
    return out.reshape(-1)[:d]
