"""Pure-jnp oracles for the Pallas kernels (L1 correctness references).

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy ops only. pytest (see python/tests/) sweeps
shapes/dtypes with hypothesis and asserts allclose between kernel and
reference. The rust-side native reimplementations (rust/src/quant/) are
cross-checked against the same math in integration tests.
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-12


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain f32 matmul oracle for kernels.matmul.matmul."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def qsgd_quantize_ref(x: jnp.ndarray, u: jnp.ndarray, s: jnp.ndarray,
                      bucket: int = 128):
    """Bucketed qsgd_s stochastic quantization oracle (Example B.1 +
    Alistarh et al.'s bucketing).

    Args:
      x: f32[d] vector to quantize.
      u: f32[d] iid U[0,1) noise driving the stochastic rounding.
      s: scalar f32, number of quantization levels.
      bucket: coordinates per l2-norm bucket.

    Returns:
      (levels, norms): levels is i32[d] holding sign(x_i) * xi_i with
      xi_i in {0..s}; norms are the per-bucket l2 norms. The receiver
      reconstructs norms[bucket(i)] / s * levels.

    xi_i = floor(|x_i| * s / ||bucket(i)|| + u_i) realizes
      ceil(a) with probability frac(a), floor(a) otherwise,
    exactly the distribution in Example B.1, so E[Q(x)] = x (unbiased).
    """
    x = x.astype(jnp.float32)
    d = x.shape[0]
    dp = ((d + bucket - 1) // bucket) * bucket
    xp = jnp.pad(x, (0, dp - d))
    norms = jnp.sqrt(jnp.sum(xp.reshape(-1, bucket) ** 2, axis=1))
    scale = (s / jnp.maximum(norms, EPS))
    scale_elem = jnp.repeat(scale, bucket)[:d]
    a = jnp.abs(x) * scale_elem
    levels = jnp.floor(a + u)
    signed = jnp.sign(x) * levels
    return signed.astype(jnp.int32), norms


def qsgd_dequantize_ref(levels: jnp.ndarray, norms: jnp.ndarray,
                        s: jnp.ndarray, bucket: int = 128) -> jnp.ndarray:
    """Inverse of qsgd_quantize_ref: norms[bucket(i)] / s * levels."""
    d = levels.shape[0]
    unit = norms / jnp.maximum(s, 1.0)
    unit_elem = jnp.repeat(unit, bucket)[:d]
    return unit_elem * levels.astype(jnp.float32)


def sgd_delta_ref(params, grads_seq, lrs):
    """Reference for a P-step SGD delta: -sum_p lr_p * g_p (fixed grads)."""
    delta = jnp.zeros_like(params)
    for g, lr in zip(grads_seq, lrs):
        delta = delta - lr * g
    return delta
